//! Multi-tenant scheduler invariant suite (ISSUE 5 tentpole).
//!
//! The core claim: **time-slicing is bit-neutral**. A job run under the
//! scheduler — preempted at arbitrary slice boundaries (checkpoint-save +
//! requeue), interleaved with other tenants on the shared runtime, even
//! elastically re-sized dp2→dp4 across a preemption — finishes with
//! `state_hash`, per-step f32 `step_losses`, eval curve and token
//! accounting bit-identical to the same run executed uninterrupted.
//!
//! Also covered: strict priorities and DRR shares shape the interleave,
//! cancel leaves a valid resumable snapshot, per-job checkpoint
//! namespaces isolate concurrent tenants, `run_cases` propagates a
//! mid-grid failure while the scheduler-backed path fails only the bad
//! job, and `run_cases_scheduled` (the `dsde pareto --jobs N` path)
//! produces the same rows as sequential `run_cases`.

use dsde::config::json::Json;
use dsde::config::schema::*;
use dsde::exp::{run_cases, run_cases_scheduled};
use dsde::orch::{request, serve_with, JobSpec, JobState, Scheduler, SchedulerConfig, ServeOptions};
use dsde::train::{RunResult, TrainEnv};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

const STEPS: u64 = 10;
const SLICE: u64 = 3;

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

fn env() -> TrainEnv {
    TrainEnv::new(200, 91).expect("surrogate runtime available")
}

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "dsde-sched-{}-{}-{}",
        std::process::id(),
        tag,
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn seqtru(max_seq: usize) -> ClConfig {
    ClConfig::new(
        Metric::SeqTru,
        Bound::Value((max_seq / 8) as f64),
        Bound::Value(max_seq as f64),
        (STEPS as f64 * 0.6) as u64,
    )
}

fn seqres(max_seq: usize) -> ClConfig {
    ClConfig::new(
        Metric::SeqRes,
        Bound::Value((max_seq / 8) as f64),
        Bound::Value(max_seq as f64),
        (STEPS as f64 * 0.6) as u64,
    )
}

fn voc() -> ClConfig {
    ClConfig::new(Metric::Voc, Bound::Percentile(0.05), Bound::Percentile(1.0), STEPS)
}

fn loss_signal() -> ClConfig {
    ClConfig::new(Metric::Loss, Bound::Percentile(0.25), Bound::Percentile(1.0), STEPS)
}

fn pdd() -> Option<PddConfig> {
    Some(PddConfig::new(0.0, 0.5, 4, (STEPS as f64 * 0.8) as u64))
}

fn ltd(r_start: usize) -> Routing {
    Routing::RandomLtd(LtdConfig::mslg(r_start, STEPS))
}

fn bypass(r_start: usize) -> Routing {
    Routing::TokenBypass(BypassConfig {
        r_start,
        total_steps: STEPS,
        schedule: LtdSchedule::Constant,
        n_special: 4,
    })
}

fn case(family: &str, label: &str, curriculum: Vec<ClConfig>, routing: Routing) -> RunConfig {
    let mut c = RunConfig::baseline(family, STEPS, 3e-3);
    c.label = label.to_string();
    c.seed = 4242;
    c.eval_every = STEPS / 2;
    c.curriculum = curriculum;
    c.routing = routing;
    c
}

fn with_knobs(base: &RunConfig, n: usize, pipeline_on: bool) -> RunConfig {
    let mut c = base.clone();
    c.n_replicas = n;
    c.pipeline = if pipeline_on {
        PipelineConfig { prefetch_depth: 3, n_loader_workers: 4 }
    } else {
        PipelineConfig::disabled()
    };
    c
}

/// Every observable the scheduler invariant guarantees, bit-exactly.
fn assert_bit_identical(label: &str, reference: &RunResult, r: &RunResult) {
    assert_eq!(reference.state_hash, r.state_hash, "{label}: final model state diverged");
    assert_eq!(reference.step_losses, r.step_losses, "{label}: per-step loss curve diverged");
    assert_eq!(reference.curve.len(), r.curve.len(), "{label}: curve length");
    for (a, b) in reference.curve.iter().zip(&r.curve) {
        assert_eq!(a.step, b.step, "{label}: curve step");
        assert_eq!(
            a.eval_loss.to_bits(),
            b.eval_loss.to_bits(),
            "{label}: eval loss diverged at step {}",
            a.step
        );
        assert_eq!(a.compute_tokens, b.compute_tokens, "{label}: token accounting");
    }
    assert_eq!(
        reference.final_eval_loss.to_bits(),
        r.final_eval_loss.to_bits(),
        "{label}: final eval"
    );
    assert_eq!(reference.data_tokens, r.data_tokens, "{label}: data tokens");
    assert_eq!(reference.pdd_dropped_tokens, r.pdd_dropped_tokens, "{label}: pdd accounting");
    assert_eq!(reference.compute_tokens, r.compute_tokens, "{label}: compute tokens");
    assert_eq!(reference.dispatch, r.dispatch, "{label}: dispatch histogram");
    assert_eq!(reference.final_accuracy, r.final_accuracy, "{label}: accuracy");
}

fn sched(max_active: usize, slice: u64) -> Scheduler {
    Scheduler::new(SchedulerConfig {
        max_active,
        default_slice: slice,
        quantum: slice.max(1),
        cleanup_done: false, // tests inspect the snapshot files
    })
}

/// The time-slicing oracle for one case at one (replicas, pipeline) point:
/// the scheduled, repeatedly-preempted run must match the uninterrupted
/// reference bit for bit.
fn check_sliced(env: &TrainEnv, base: &RunConfig, n: usize, pipeline_on: bool) {
    let label = format!(
        "{} ({}, dp{}, pipeline {})",
        base.label,
        base.family,
        n,
        if pipeline_on { "on" } else { "off" }
    );
    let reference = env
        .run(with_knobs(base, n, pipeline_on))
        .unwrap_or_else(|e| panic!("{label} reference: {e:#}"));

    let dir = temp_dir(&base.label);
    let mut cfg = with_knobs(base, n, pipeline_on);
    cfg.save_dir = dir.to_string_lossy().into_owned();
    let mut s = sched(4, SLICE);
    let id = s.submit(JobSpec::new(cfg)).unwrap();
    s.drain(env).unwrap_or_else(|e| panic!("{label} drain: {e:#}"));

    let job = s.job(id).unwrap();
    assert_eq!(job.state, JobState::Done, "{label}: {:?}", job.error);
    assert_eq!(job.completed_steps, STEPS, "{label}: completed steps");
    assert_eq!(job.slices, STEPS.div_ceil(SLICE), "{label}: slice count");
    assert_eq!(job.preemptions, STEPS.div_ceil(SLICE) - 1, "{label}: preemption count");
    let r = job.result.as_ref().expect("done job has a result");
    assert_bit_identical(&format!("{label} [time-sliced]"), &reference, r);
    let _ = std::fs::remove_dir_all(&dir);
}

fn check_case(env: &TrainEnv, base: RunConfig, pipelines: &[bool], replicas: &[usize]) {
    for &pipeline_on in pipelines {
        for &n in replicas {
            check_sliced(env, &base, n, pipeline_on);
        }
    }
}

// ---- Bit-identity across the case matrix ---------------------------------

#[test]
fn gpt_seqtru_ltd_sliced() {
    let env = env();
    check_case(
        &env,
        case("gpt", "gpt-seqtru+ltd", vec![seqtru(64)], ltd(16)),
        &[true, false],
        &[0, 2],
    );
}

#[test]
fn gpt_seqres_voc_bypass_sliced() {
    let env = env();
    check_case(
        &env,
        case("gpt", "gpt-seqres+voc+bypass", vec![seqres(64), voc()], bypass(32)),
        &[true],
        &[0, 2],
    );
}

#[test]
fn bert_seqtru_ltd_sliced() {
    let env = env();
    check_case(
        &env,
        case("bert", "bert-seqtru+ltd", vec![seqtru(64)], ltd(16)),
        &[true, false],
        &[0, 2],
    );
}

#[test]
fn moe_seqtru_ltd_sliced() {
    let env = env();
    check_case(
        &env,
        case("moe", "moe-seqtru+ltd", vec![seqtru(64)], ltd(16)),
        &[true, false],
        &[0, 2],
    );
}

#[test]
fn moe_voc_bypass_sliced() {
    let env = env();
    check_case(&env, case("moe", "moe-voc+bypass", vec![voc()], bypass(32)), &[true], &[0, 2]);
}

#[test]
fn gpt_pdd_ltd_sliced() {
    let env = env();
    let mut c = case("gpt", "gpt-pdd+seqtru+ltd", vec![seqtru(64)], ltd(16));
    c.pdd = pdd();
    check_case(&env, c, &[true, false], &[0, 2]);
}

#[test]
fn moe_loss_signal_pdd_sliced() {
    // SLICE = 3 makes every preemption boundary coincide with a
    // loss-signal publish boundary (epoch ceil(10/4) = 3) — the hardest
    // alignment for the restore-then-republish resume rule.
    let env = env();
    let mut c = case("moe", "moe-loss-signal+pdd", vec![loss_signal()], Routing::None);
    c.pdd = pdd();
    check_case(&env, c, &[true], &[0, 2]);
}

#[test]
fn vit_ltd_sliced() {
    let env = env();
    check_case(&env, case("vit", "vit-ltd", vec![], ltd(5)), &[true, false], &[0, 2]);
}

// ---- Multi-tenant interleaving -------------------------------------------

#[test]
fn interleaved_tenants_stay_bit_exact() {
    let env = env();
    let bases = [
        case("gpt", "tenant-gpt", vec![seqtru(64)], ltd(16)),
        case("bert", "tenant-bert", vec![seqtru(64)], ltd(16)),
        case("vit", "tenant-vit", vec![], ltd(5)),
    ];
    let references: Vec<RunResult> = bases
        .iter()
        .map(|b| env.run(with_knobs(b, 0, true)).expect("reference"))
        .collect();

    let dir = temp_dir("tenants");
    let mut s = sched(4, SLICE);
    let ids: Vec<u64> = bases
        .iter()
        .map(|b| {
            let mut cfg = with_knobs(b, 0, true);
            cfg.save_dir = dir.to_string_lossy().into_owned();
            s.submit(JobSpec::new(cfg)).unwrap()
        })
        .collect();
    s.drain(&env).unwrap();

    for (id, reference) in ids.iter().zip(&references) {
        let job = s.job(*id).unwrap();
        assert_eq!(job.state, JobState::Done, "job {id}: {:?}", job.error);
        assert!(job.preemptions >= 2, "job {id} was barely time-sliced");
        assert_bit_identical(
            &format!("tenant {id}"),
            reference,
            job.result.as_ref().unwrap(),
        );
    }
    // the executor genuinely interleaved (round-robin ring visible)
    let log = s.slice_log();
    let switches = log.windows(2).filter(|w| w[0].0 != w[1].0).count();
    assert!(switches >= 4, "no real interleaving: {log:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- Elastic dp2 → dp4 re-size across a preemption ------------------------

#[test]
fn elastic_dp2_to_dp4_across_preemption() {
    let env = env();
    let base = case("gpt", "gpt-elastic", vec![seqtru(64)], ltd(16));
    let reference = env.run(with_knobs(&base, 4, true)).expect("dp4 reference");

    let dir = temp_dir("elastic");
    let mut cfg = with_knobs(&base, 2, true);
    cfg.save_dir = dir.to_string_lossy().into_owned();
    let mut s = sched(4, 4);
    let id = s.submit(JobSpec::new(cfg)).unwrap();
    let picked = s.next_job().unwrap();
    assert_eq!(picked, id);
    s.run_slice(&env, id).unwrap();
    assert_eq!(s.job(id).unwrap().state, JobState::Preempted);
    assert_eq!(s.job(id).unwrap().completed_steps, 4);

    // elastic re-size while preempted: legal within the replica engine
    s.resize_replicas(id, 4).unwrap();
    s.drain(&env).unwrap();
    let job = s.job(id).unwrap();
    assert_eq!(job.state, JobState::Done, "{:?}", job.error);
    assert_bit_identical("elastic dp2→dp4", &reference, job.result.as_ref().unwrap());

    // crossing the engine boundary would have been rejected up front
    let mut s2 = sched(4, 4);
    let mut cfg2 = with_knobs(&base, 2, true);
    cfg2.save_dir = dir.to_string_lossy().into_owned();
    let id2 = s2.submit(JobSpec::new(cfg2)).unwrap();
    let picked = s2.next_job().unwrap();
    s2.run_slice(&env, picked).unwrap();
    let err = s2.resize_replicas(id2, 0).unwrap_err();
    assert!(format!("{err}").contains("engine"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- Priorities and shares ------------------------------------------------

#[test]
fn strict_priority_runs_high_class_first() {
    let env = env();
    let dir = temp_dir("prio");
    let mut s = sched(4, SLICE);
    let mk = |label: &str, priority: u32| {
        let mut cfg = case("gpt", label, vec![seqtru(64)], ltd(16));
        cfg.save_dir = dir.to_string_lossy().into_owned();
        let mut spec = JobSpec::new(cfg);
        spec.priority = priority;
        spec
    };
    let lo = s.submit(mk("low-pri", 1)).unwrap();
    let hi = s.submit(mk("high-pri", 2)).unwrap();
    s.drain(&env).unwrap();
    assert_eq!(s.job(lo).unwrap().state, JobState::Done);
    assert_eq!(s.job(hi).unwrap().state, JobState::Done);
    // every high-priority slice precedes every low-priority slice
    let log = s.slice_log();
    let first_lo = log.iter().position(|&(id, _)| id == lo).unwrap();
    let last_hi = log.iter().rposition(|&(id, _)| id == hi).unwrap();
    assert!(
        last_hi < first_lo,
        "high-priority job must fully drain before the low class runs: {log:?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn drr_share_weights_the_interleave() {
    let env = env();
    let dir = temp_dir("share");
    let mut s = Scheduler::new(SchedulerConfig {
        max_active: 4,
        default_slice: 2,
        quantum: 1,
        cleanup_done: false,
    });
    let mk = |label: &str, share: u32| {
        let mut cfg = case("gpt", label, vec![seqtru(64)], ltd(16));
        cfg.total_steps = 8;
        cfg.eval_every = 4;
        cfg.save_dir = dir.to_string_lossy().into_owned();
        let mut spec = JobSpec::new(cfg);
        spec.share = share;
        spec
    };
    let heavy = s.submit(mk("share-2", 2)).unwrap();
    let light = s.submit(mk("share-1", 1)).unwrap();
    s.drain(&env).unwrap();
    assert_eq!(s.job(heavy).unwrap().state, JobState::Done);
    assert_eq!(s.job(light).unwrap().state, JobState::Done);
    let log = s.slice_log();
    // proportional fair share: the share-2 tenant earns credit twice as
    // fast, so it front-loads the schedule and finishes first
    let heavy_first3 = log.iter().take(3).filter(|&&(id, _)| id == heavy).count();
    assert!(heavy_first3 >= 2, "share-2 job under-served early: {log:?}");
    let last_heavy = log.iter().rposition(|&(id, _)| id == heavy).unwrap();
    let last_light = log.iter().rposition(|&(id, _)| id == light).unwrap();
    assert!(last_heavy < last_light, "share-2 job must finish first: {log:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn speculative_picks_do_not_skew_shares() {
    // Regression (fairness skew): `next_job` used to accrue DRR deficits
    // as a side effect, so idle polling or lookahead without a matching
    // `run_slice` inflated credits and bent the share ratios. The pick is
    // now pure — a drain interleaved with heavy speculative picking must
    // produce the exact same slice log as an undisturbed drain.
    let env = env();
    let run = |tag: &str, spurious_picks: usize| {
        let dir = temp_dir(tag);
        let mut s = Scheduler::new(SchedulerConfig {
            max_active: 4,
            default_slice: 2,
            quantum: 1,
            cleanup_done: false,
        });
        for (label, share) in [("pure-2", 2u32), ("pure-1", 1u32)] {
            let mut cfg = case("gpt", label, vec![seqtru(64)], ltd(16));
            cfg.total_steps = 8;
            cfg.eval_every = 4;
            cfg.save_dir = dir.to_string_lossy().into_owned();
            let mut spec = JobSpec::new(cfg);
            spec.share = share;
            s.submit(spec).unwrap();
        }
        loop {
            for _ in 0..spurious_picks {
                let _ = s.next_job(); // idle polling / lookahead
            }
            match s.next_job() {
                Some(id) => s.run_slice(&env, id).unwrap(),
                None => break,
            }
        }
        let log = s.slice_log().to_vec();
        let deficits: Vec<i64> = s.jobs().iter().map(|j| j.deficit()).collect();
        let _ = std::fs::remove_dir_all(&dir);
        (log, deficits)
    };
    let (clean_log, clean_deficits) = run("purepick-clean", 0);
    let (polled_log, polled_deficits) = run("purepick-polled", 50);
    assert_eq!(clean_log, polled_log, "speculative picks changed the schedule");
    assert_eq!(clean_deficits, polled_deficits, "speculative picks inflated DRR credit");
}

// ---- Cancel ---------------------------------------------------------------

#[test]
fn cancel_leaves_a_valid_resumable_checkpoint() {
    let env = env();
    let base = case("gpt", "gpt-cancel", vec![seqtru(64)], ltd(16));
    let reference = env.run(with_knobs(&base, 0, true)).expect("reference");

    let dir = temp_dir("cancel");
    let mut cfg = with_knobs(&base, 0, true);
    cfg.save_dir = dir.to_string_lossy().into_owned();
    let mut s = sched(4, SLICE);
    let id = s.submit(JobSpec::new(cfg)).unwrap();
    let picked = s.next_job().unwrap();
    s.run_slice(&env, picked).unwrap();
    s.cancel(id).unwrap();

    let job = s.job(id).unwrap();
    assert_eq!(job.state, JobState::Cancelled);
    let ck = job.checkpoint.clone().expect("cancelled job keeps its snapshot");
    assert!(ck.exists(), "{} missing", ck.display());
    assert_eq!(s.next_job(), None, "cancelled job never reschedules");

    // the kept snapshot is an ordinary checkpoint: resuming from it
    // completes the run bit-identically
    let mut resuming = with_knobs(&base, 0, true);
    resuming.resume = Some(ck.to_string_lossy().into_owned());
    let resumed = env.run(resuming).expect("resume from cancelled job's snapshot");
    assert_eq!(resumed.resumed_at, SLICE);
    assert_bit_identical("cancel → manual resume", &reference, &resumed);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- Namespace isolation --------------------------------------------------

#[test]
fn concurrent_jobs_share_a_save_dir_without_clobbering() {
    let env = env();
    let dir = temp_dir("ns");
    let mut s = sched(4, SLICE);
    let mut ids = Vec::new();
    for label in ["ns-a", "ns-b"] {
        let mut cfg = case("gpt", label, vec![seqtru(64)], ltd(16));
        cfg.save_dir = dir.to_string_lossy().into_owned(); // the SAME dir
        ids.push(s.submit(JobSpec::new(cfg)).unwrap());
    }
    // one slice each: both jobs now have a step000003.ckpt — which would
    // collide without per-job namespaces
    for _ in 0..2 {
        let id = s.next_job().unwrap();
        s.run_slice(&env, id).unwrap();
    }
    let cks: Vec<PathBuf> = ids
        .iter()
        .map(|&id| s.job(id).unwrap().checkpoint.clone().expect("boundary snapshot"))
        .collect();
    assert_ne!(cks[0], cks[1], "same save_dir, same step — paths must differ");
    for (id, ck) in ids.iter().zip(&cks) {
        assert!(ck.exists(), "{} missing", ck.display());
        assert!(
            ck.to_string_lossy().contains(&format!("job-{id:06}")),
            "{} not namespaced",
            ck.display()
        );
    }
    s.drain(&env).unwrap();
    // identical configs in disjoint namespaces converge to identical runs
    let ra = s.job(ids[0]).unwrap().result.as_ref().unwrap().clone();
    let rb = s.job(ids[1]).unwrap().result.as_ref().unwrap();
    assert_bit_identical("namespaced twins", &ra, rb);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- Failure isolation + run_cases error propagation ----------------------

#[test]
fn run_cases_propagates_a_mid_grid_failure() {
    let env = env();
    let good = case("gpt", "good", vec![], Routing::None);
    let mut bad = case("gpt", "bad", vec![], Routing::None);
    bad.family = "not-a-family".into();
    // sequential runner: the `?` path surfaces the error to the caller
    let err = run_cases(&env, vec![good.clone(), bad.clone(), good.clone()]).unwrap_err();
    assert!(format!("{err:#}").contains("not-a-family"), "{err:#}");
}

#[test]
fn scheduler_fails_only_the_bad_job() {
    let env = env();
    let dir = temp_dir("fail");
    let good = case("gpt", "good", vec![seqtru(64)], ltd(16));
    let mut bad = good.clone();
    bad.label = "bad".into();
    bad.family = "not-a-family".into();

    let mut s = sched(4, SLICE);
    let mut submit = |cfg: &RunConfig| {
        let mut cfg = cfg.clone();
        cfg.save_dir = dir.to_string_lossy().into_owned();
        s.submit(JobSpec::new(cfg)).unwrap()
    };
    let a = submit(&good);
    let b = submit(&bad);
    let c = submit(&good);
    s.drain(&env).unwrap();
    assert_eq!(s.job(a).unwrap().state, JobState::Done);
    assert_eq!(s.job(c).unwrap().state, JobState::Done);
    let failed = s.job(b).unwrap();
    assert_eq!(failed.state, JobState::Failed);
    assert!(
        failed.error.as_deref().unwrap_or("").contains("not-a-family"),
        "{:?}",
        failed.error
    );
    assert_eq!(s.stats().failed, 1);
    assert_eq!(s.stats().completed, 2);

    // the grid wrapper reports the failure after completing the rest
    let err = run_cases_scheduled(
        &env,
        vec![good.clone(), bad, good],
        2,
        SLICE,
        &dir.to_string_lossy(),
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("'bad'"), "{msg}");
    assert!(msg.contains("rest of the grid completed"), "{msg}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- TCP control plane end-to-end -----------------------------------------

#[test]
fn control_plane_end_to_end() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let dir = temp_dir("ctl");
    let save_dir = dir.to_string_lossy().into_owned();
    // The executor thread owns the environment (the runtime is
    // single-threaded by design); clients talk over the wire.
    let server = std::thread::spawn(move || {
        let env = env();
        serve_with(
            &env,
            listener,
            ServeOptions {
                sched: SchedulerConfig {
                    max_active: 2,
                    default_slice: SLICE,
                    quantum: SLICE,
                    cleanup_done: false,
                },
                default_family: "gpt".into(),
                ..ServeOptions::default()
            },
        )
        .expect("serve_with")
    });

    let mut cfg = case("gpt", "wire-job", vec![seqtru(64)], ltd(16));
    cfg.save_dir = save_dir;
    let resp = request(
        &addr,
        &Json::obj(vec![("cmd", "SUBMIT".into()), ("config", cfg.to_json())]),
    )
    .expect("SUBMIT");
    assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
    let id = resp.get("job").as_usize().expect("job id");

    // poll STATUS until the job drains through Queued/Running/Preempted
    let deadline = Instant::now() + Duration::from_secs(300);
    loop {
        let st = request(
            &addr,
            &Json::obj(vec![("cmd", "STATUS".into()), ("job", id.into())]),
        )
        .expect("STATUS");
        let state = st.path("job.state").as_str().unwrap_or("?").to_string();
        if state == "done" {
            assert_eq!(
                st.path("job.completed_steps").as_usize(),
                Some(STEPS as usize),
                "{st:?}"
            );
            break;
        }
        assert_ne!(state, "failed", "{st:?}");
        assert!(Instant::now() < deadline, "job stuck in state {state}");
        std::thread::sleep(Duration::from_millis(50));
    }

    // STATS shows the job was genuinely time-sliced on the shared runtime
    let stats = request(&addr, &Json::obj(vec![("cmd", "STATS".into())])).expect("STATS");
    assert!(stats.get("preemptions").as_usize().unwrap_or(0) >= 1, "{stats:?}");
    assert_eq!(stats.get("completed").as_usize(), Some(1), "{stats:?}");

    // unknown commands and bad cancels error cleanly, not fatally
    let bad = request(&addr, &Json::obj(vec![("cmd", "NOPE".into())])).expect("bad cmd");
    assert_eq!(bad.get("ok").as_bool(), Some(false), "{bad:?}");
    let bad = request(
        &addr,
        &Json::obj(vec![("cmd", "CANCEL".into()), ("job", 99usize.into())]),
    )
    .expect("bad cancel");
    assert_eq!(bad.get("ok").as_bool(), Some(false), "{bad:?}");

    // DRAIN shuts the server down once every job is terminal
    let dr = request(&addr, &Json::obj(vec![("cmd", "DRAIN".into())])).expect("DRAIN");
    assert_eq!(dr.get("ok").as_bool(), Some(true), "{dr:?}");
    let final_stats = server.join().expect("server thread");
    assert_eq!(final_stats.completed, 1);
    assert!(final_stats.preemptions >= 1);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- dsde pareto --jobs N parity ------------------------------------------

#[test]
fn scheduled_grid_matches_sequential_rows() {
    let env = env();
    let fam = env.rt.registry.family("gpt").unwrap().clone();
    let pairs = dsde::exp::cases::fig2_pairs(STEPS, fam.max_seq, 1234, &[0.5, 1.0]);
    let dir = temp_dir("pareto");
    for (f, base, comp) in pairs {
        let cases = vec![base, comp];
        let sequential = run_cases(&env, cases.clone()).expect("sequential grid");
        let scheduled =
            run_cases_scheduled(&env, cases, 2, SLICE, &dir.to_string_lossy())
                .expect("scheduled grid");
        assert_eq!(sequential.len(), scheduled.len());
        for (a, b) in sequential.iter().zip(&scheduled) {
            assert_eq!(a.label, b.label, "fraction {f}: submission order preserved");
            assert_bit_identical(&format!("pareto row {} @{f}", a.label), a, b);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
