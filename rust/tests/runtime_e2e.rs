//! Runtime integration: JIT-specialize surrogate programs, execute
//! init/train/eval steps directly against the PJRT client, and verify
//! numeric behavior end to end (Python is not involved — programs are
//! synthesized in-process).

use dsde::config::schema::DispatchPolicy;
use dsde::runtime::{get_f32, lit_f32, lit_i32, scalar_f32, scalar_u32, Mode, Runtime};

fn runtime() -> Runtime {
    Runtime::open_default().expect("builtin registry")
}

/// Build a deterministic fake LM batch.
fn lm_batch(rows: usize, seq: usize, vocab: i32) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
    let n = rows * seq;
    let tokens: Vec<i32> = (0..n).map(|i| 6 + (i as i32 * 37) % (vocab - 6)).collect();
    let targets: Vec<i32> = (0..n).map(|i| 6 + (i as i32 * 53) % (vocab - 6)).collect();
    (tokens, targets, vec![1.0; n])
}

#[test]
fn init_is_deterministic_per_seed() {
    let rt = runtime();
    let init = rt.step("gpt_init").unwrap();
    let a = init.execute(&[scalar_u32(1)]).unwrap();
    let b = init.execute(&[scalar_u32(1)]).unwrap();
    let c = init.execute(&[scalar_u32(2)]).unwrap();
    let av = a[0].to_vec::<f32>().unwrap();
    let bv = b[0].to_vec::<f32>().unwrap();
    let cv = c[0].to_vec::<f32>().unwrap();
    assert_eq!(av, bv);
    assert_ne!(av, cv);
    // Adam moments start at zero
    let n = a.len() / 3;
    let m0 = a[n].to_vec::<f32>().unwrap();
    assert!(m0.iter().all(|&x| x == 0.0));
}

#[test]
fn train_step_reduces_loss_on_repeated_batch() {
    let rt = runtime();
    let fam = rt.registry.family("gpt").unwrap().clone();
    let init = rt.step("gpt_init").unwrap();
    let train = rt.step("gpt_train_s16_full").unwrap();
    let mut state = init.execute(&[scalar_u32(0)]).unwrap();
    let n_state = state.len();
    let (tokens, targets, mask) = lm_batch(fam.batch, 16, fam.vocab as i32);
    let dims = [fam.batch, 16];
    let mut losses = Vec::new();
    for t in 1..=10 {
        let mut args = Vec::new();
        for l in &state {
            args.push(l.clone());
        }
        args.push(scalar_f32(t as f32));
        args.push(scalar_f32(5e-3));
        args.push(lit_i32(&tokens, &dims).unwrap());
        args.push(lit_i32(&targets, &dims).unwrap());
        args.push(lit_f32(&mask, &dims).unwrap());
        let out = train.execute(&args).unwrap();
        losses.push(get_f32(&out[n_state]).unwrap());
        state = out.into_iter().take(n_state).collect();
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.8),
        "memorizing one batch must drop loss fast: {losses:?}"
    );
}

#[test]
fn ltd_variant_executes_with_keep_indices() {
    let rt = runtime();
    let fam = rt.registry.family("gpt").unwrap().clone();
    let init = rt.step("gpt_init").unwrap();
    let train = rt.step("gpt_train_s64_ltd32").unwrap();
    let state = init.execute(&[scalar_u32(3)]).unwrap();
    let n_state = state.len();
    let (tokens, targets, mask) = lm_batch(fam.batch, 64, fam.vocab as i32);
    let dims = [fam.batch, 64];
    let n_mid = fam.n_middle_layers;
    // keep even positions in every middle layer
    let keep: Vec<i32> = (0..n_mid).flat_map(|_| (0..32).map(|i| i * 2)).collect();
    let mut args: Vec<xla::Literal> = state.iter().cloned().collect();
    args.push(scalar_f32(1.0));
    args.push(scalar_f32(1e-3));
    args.push(lit_i32(&tokens, &dims).unwrap());
    args.push(lit_i32(&targets, &dims).unwrap());
    args.push(lit_f32(&mask, &dims).unwrap());
    args.push(lit_i32(&keep, &[n_mid, 32]).unwrap());
    let out = train.execute(&args).unwrap();
    let loss = get_f32(&out[n_state]).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
}

#[test]
fn eval_step_token_weighted() {
    let rt = runtime();
    let fam = rt.registry.family("gpt").unwrap().clone();
    let init = rt.step("gpt_init").unwrap();
    let eval = rt.step(&rt.registry.eval_name("gpt").unwrap()).unwrap();
    let state = init.execute(&[scalar_u32(0)]).unwrap();
    let n_params = rt.registry.family("gpt").unwrap().n_params;
    let (tokens, targets, _) = lm_batch(fam.batch, 64, fam.vocab as i32);
    let dims = [fam.batch, 64];
    // half-masked loss: tok count must reflect the mask sum
    let mut mask = vec![0.0f32; fam.batch * 64];
    for (i, m) in mask.iter_mut().enumerate() {
        if i % 2 == 0 {
            *m = 1.0;
        }
    }
    let mut args: Vec<xla::Literal> = state[..n_params].iter().cloned().collect();
    args.push(lit_i32(&tokens, &dims).unwrap());
    args.push(lit_i32(&targets, &dims).unwrap());
    args.push(lit_f32(&mask, &dims).unwrap());
    let out = eval.execute(&args).unwrap();
    let loss_sum = get_f32(&out[0]).unwrap();
    let tok = get_f32(&out[1]).unwrap();
    assert_eq!(tok, (fam.batch * 32) as f32);
    // fresh init ≈ uniform predictions: mean loss near ln(vocab)
    let mean = loss_sum / tok;
    assert!((5.0..7.5).contains(&mean), "init loss {mean}");
}

#[test]
fn route_then_execute_all_families() {
    let rt = runtime();
    for fam_name in ["gpt", "bert", "vit", "moe"] {
        let fam = rt.registry.family(fam_name).unwrap().clone();
        let route = rt
            .registry
            .route_train(fam_name, fam.max_seq, fam.max_seq / 2, Mode::Ltd, DispatchPolicy::Bucket)
            .unwrap();
        let exe = rt.step(&route.artifact).unwrap();
        assert_eq!(exe.info.family, fam_name);
        assert!(exe.info.keep > 0, "{fam_name} routed to {}", route.artifact);
    }
}
