//! Randomized property tests over the schedule/accounting algebra, built
//! on `testutil::property` (the in-tree proptest substitute).
//!
//! * pacing functions are monotone non-decreasing and clamped to
//!   `[d_start, d_end]`;
//! * the `TokenAccountant` conserves layer tokens (kept + dropped ==
//!   consumed) under composed CL + LTD schedules;
//! * seqres preserves the token count of every sampled sequence, while
//!   seqtru strictly reduces it (the §3.1 distinction between the two
//!   length transforms);
//! * JSON wire integers round-trip losslessly across the full u64/i64
//!   range (the control plane's job ids), and integers no integer type
//!   can represent exactly are rejected, never silently truncated
//!   (ISSUE 6 precision satellite).

use dsde::config::schema::*;
use dsde::curriculum::loader::{BatchPlan, LoaderCore};
use dsde::curriculum::scheduler::{ClScheduler, ClState, SeqTransform};
use dsde::curriculum::{GptLoader, UniformSampler};
use dsde::data::corpus::{Corpus, CorpusConfig};
use dsde::data::dataset::GptDataset;
use dsde::data::tokenizer::Tokenizer;
use dsde::ltd::schedule::kept_len;
use dsde::ltd::TokenAccountant;
use dsde::testutil::property;
use std::sync::Arc;

#[test]
fn prop_pacing_monotone_and_clamped() {
    property("pacing monotone + clamped", 24, |rng| {
        let pacing = match rng.gen_range(4) {
            0 => Pacing::Linear,
            1 => Pacing::Sqrt,
            2 => Pacing::Power(0.1 + rng.next_f64() * 3.0),
            _ => Pacing::Step(1 + rng.gen_range(9)),
        };
        let d_start = rng.next_f64() * 100.0;
        let d_end = d_start + rng.next_f64() * 100.0;
        let total = 1 + rng.gen_range(200) as u64;
        let mut prev = f64::MIN;
        for t in 0..=(total + total / 2 + 2) {
            let d = dsde::curriculum::pacing::pace(pacing, d_start, d_end, t, total);
            if d < d_start - 1e-9 || d > d_end + 1e-9 {
                return Err(format!("{pacing:?}: d_t {d} outside [{d_start}, {d_end}] at t={t}"));
            }
            if d < prev - 1e-9 {
                return Err(format!("{pacing:?}: not monotone at t={t}: {d} < {prev}"));
            }
            prev = d;
        }
        // and the schedule must reach its end difficulty
        let d_final = dsde::curriculum::pacing::pace(pacing, d_start, d_end, total, total);
        if (d_final - d_end).abs() > 1e-9 {
            return Err(format!("{pacing:?}: end {d_final} != d_end {d_end}"));
        }
        Ok(())
    });
}

#[test]
fn prop_accountant_conserves_tokens_under_composed_schedules() {
    property("accountant conservation", 16, |rng| {
        let max_seq = 64usize;
        let n_layers = 2 + rng.gen_range(6) as usize;
        let n_mid = rng.gen_range(n_layers as u32 - 1) as usize;
        let total_steps = 20 + rng.gen_range(80) as u64;
        let batch = 1 + rng.gen_range(8) as usize;
        // composed CL (seqtru) + LTD (mslg or constant) schedules
        let cl = ClConfig::new(
            Metric::SeqTru,
            Bound::Value((4 + rng.gen_range(16)) as f64),
            Bound::Value(max_seq as f64),
            1 + rng.gen_range(total_steps as u32) as u64,
        );
        let ltd = if rng.next_f32() < 0.5 {
            LtdConfig::mslg(1 + rng.gen_range(32) as usize, 1 + rng.gen_range(total_steps as u32) as u64)
        } else {
            LtdConfig::constant(1 + rng.gen_range(32) as usize, 1 + rng.gen_range(total_steps as u32) as u64)
        };
        let sched = ClScheduler::new(&[cl], max_seq).unwrap();
        let mut acct = TokenAccountant::new(n_layers);
        let mut expect_consumed = 0u64;
        let mut expect_dropped = 0u64;
        for step in 0..total_steps {
            let seq = sched.state_at(step).seq;
            let kept = kept_len(&ltd, step, seq);
            let dropping = kept < seq;
            let drop_layers = if dropping { n_mid } else { 0 };
            acct.record(batch, seq, kept, drop_layers);
            expect_consumed += (batch * seq * n_layers) as u64;
            expect_dropped += (batch * (seq - kept) * drop_layers) as u64;
        }
        // conservation: kept + dropped == consumed (per layer-token)
        let kept = acct.kept_layer_tokens();
        let dropped = acct.dropped_layer_tokens();
        if kept + dropped != expect_consumed {
            return Err(format!(
                "kept {kept} + dropped {dropped} != consumed {expect_consumed}"
            ));
        }
        if dropped != expect_dropped {
            return Err(format!("dropped {dropped} != schedule-derived {expect_dropped}"));
        }
        // and the derived ratios stay in range
        let s = acct.saving_ratio();
        if !(0.0..=1.0).contains(&s) {
            return Err(format!("saving ratio {s} out of range"));
        }
        Ok(())
    });
}

#[test]
fn prop_seqres_preserves_and_seqtru_reduces_tokens() {
    let c = Corpus::generate(CorpusConfig { n_docs: 250, seed: 13, ..Default::default() });
    let t = Tokenizer::from_corpus(&c);
    let ds = Arc::new(GptDataset::build(&c, &t, 64));
    let n = ds.n_samples();
    property("seqres preserves / seqtru reduces", 12, |rng| {
        let batch = 8usize;
        // a bucketed sub-sequence length strictly below max
        let seq = [8usize, 16, 32][rng.gen_range(3) as usize];
        let mut loader = GptLoader::new(
            ds.clone(),
            Box::new(UniformSampler::new(n, rng.next_u64())),
            batch,
        );
        let core: LoaderCore = loader.core();

        // --- seqres: every sampled sequence is used in full (reshaped into
        // segs rows), so tokens used == sampled sequences × max_seq.
        let st = ClState { seq, transform: SeqTransform::Reshape, pool_pct: 1.0, pdd_frac: 0.0 };
        let plan = loader.plan_batch(seq, &st);
        let segs = 64 / seq;
        let expect_ids = batch.div_ceil(segs);
        if plan.ids.len() != expect_ids {
            return Err(format!("seqres drew {} ids, want {expect_ids}", plan.ids.len()));
        }
        let batch_out = match core.materialize(&BatchPlan::Lm(plan.clone()), None) {
            dsde::curriculum::AnyBatch::Lm(b) => b,
            _ => return Err("wrong batch kind".into()),
        };
        let used = batch_out.tokens.len();
        let sampled = plan.ids.len() * 64;
        if used != sampled {
            return Err(format!(
                "seqres must preserve per-sequence token counts: used {used} != sampled {sampled}"
            ));
        }
        if batch_out.data_tokens != (batch * seq) as u64 {
            return Err("seqres batch data_tokens mismatch".into());
        }

        // --- seqtru: one sequence per row, truncated — strictly fewer
        // tokens used than sampled whenever seq < max_seq.
        let st = ClState { seq, transform: SeqTransform::Truncate, pool_pct: 1.0, pdd_frac: 0.0 };
        let plan = loader.plan_batch(seq, &st);
        if plan.ids.len() != batch {
            return Err(format!("seqtru draws one id per row, got {}", plan.ids.len()));
        }
        let batch_out = match core.materialize(&BatchPlan::Lm(plan.clone()), None) {
            dsde::curriculum::AnyBatch::Lm(b) => b,
            _ => return Err("wrong batch kind".into()),
        };
        let used = batch_out.tokens.len();
        let sampled = plan.ids.len() * 64;
        if used >= sampled {
            return Err(format!(
                "seqtru must strictly reduce tokens used: used {used} >= sampled {sampled}"
            ));
        }
        if used != batch * seq {
            return Err(format!("seqtru batch holds {used} tokens, want {}", batch * seq));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// ShardPlan (ISSUE 2 satellite): the data-parallel batch partitioner.

#[test]
fn prop_shard_plan_is_an_exact_partition() {
    use dsde::curriculum::loader::ShardPlan;
    property("shard plan partitions exactly", 32, |rng| {
        let rows = 1 + rng.gen_range(64) as usize;
        let n_ranks = 1 + rng.gen_range(rows as u32 + 4) as usize;
        let plan = ShardPlan::new(rows, n_ranks);
        if plan.n_ranks() != n_ranks {
            return Err(format!("rank count {} != {n_ranks}", plan.n_ranks()));
        }
        // every global row lands on exactly one rank, in order
        let mut covered = 0usize;
        let mut loads = Vec::new();
        for r in 0..plan.n_ranks() {
            let range = plan.range(r);
            if range.start != covered {
                return Err(format!("rank {r} starts at {} but {covered} rows assigned", range.start));
            }
            covered = range.end;
            loads.push(plan.rows_of(r));
        }
        if covered != rows {
            return Err(format!("{covered} of {rows} rows covered"));
        }
        // per-rank loads differ by at most 1
        let max = *loads.iter().max().unwrap();
        let min = *loads.iter().min().unwrap();
        if max - min > 1 {
            return Err(format!("loads {loads:?} differ by more than 1"));
        }
        if plan.imbalance() != max - min {
            return Err("imbalance() disagrees with loads".into());
        }
        // aligned() iff equal power-of-two shards
        let aligned = rows % n_ranks == 0 && (rows / n_ranks).max(1).is_power_of_two();
        if plan.aligned() != aligned {
            return Err(format!("aligned() = {} for rows={rows} ranks={n_ranks}", plan.aligned()));
        }
        Ok(())
    });
}

#[test]
fn prop_shard_plan_invariant_to_worker_scheduling() {
    use dsde::curriculum::loader::ShardPlan;
    // The plan is a pure function of (rows, n_ranks): constructing it from
    // many racing threads, in any order, yields identical partitions.
    property("shard plan scheduling-invariant", 8, |rng| {
        let rows = 1 + rng.gen_range(32) as usize;
        let n_ranks = 1 + rng.gen_range(8) as usize;
        let reference = ShardPlan::new(rows, n_ranks);
        let plans: Vec<ShardPlan> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|_| scope.spawn(move || ShardPlan::new(rows, n_ranks)))
                .collect();
            handles.into_iter().map(|h| h.join().expect("no panic")).collect()
        });
        for p in plans {
            if p != reference {
                return Err("plan depends on construction context".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_shard_slices_reassemble_global_batch() {
    use dsde::curriculum::loader::ShardPlan;
    let c = Corpus::generate(CorpusConfig { n_docs: 200, seed: 31, ..Default::default() });
    let t = Tokenizer::from_corpus(&c);
    let ds = Arc::new(GptDataset::build(&c, &t, 64));
    let n = ds.n_samples();
    property("shards reassemble the batch", 8, |rng| {
        let mut loader = GptLoader::new(
            ds.clone(),
            Box::new(UniformSampler::new(n, rng.next_u64())),
            8,
        );
        let seq = [8usize, 16, 32, 64][rng.gen_range(4) as usize];
        let st = ClState { seq, transform: SeqTransform::Truncate, pool_pct: 1.0, pdd_frac: 0.0 };
        let b = loader.next_batch(seq, &st);
        let n_ranks = [1usize, 2, 3, 4, 5, 8][rng.gen_range(6) as usize];
        let plan = ShardPlan::new(b.rows, n_ranks);
        let mut tokens = Vec::new();
        let mut targets = Vec::new();
        let mut masks = Vec::new();
        let mut dt = 0u64;
        for r in 0..plan.n_ranks() {
            let s = plan.shard_lm(&b, r);
            if s.rows != plan.rows_of(r) || s.seq != seq {
                return Err(format!("shard {r} shape {}x{}", s.rows, s.seq));
            }
            tokens.extend_from_slice(&s.tokens);
            targets.extend_from_slice(&s.targets);
            masks.extend_from_slice(&s.loss_mask);
            dt += s.data_tokens;
        }
        if tokens != b.tokens || targets != b.targets || masks != b.loss_mask {
            return Err("concatenated shards differ from the global batch".into());
        }
        if dt != b.data_tokens {
            return Err(format!("shard data_tokens sum {dt} != {}", b.data_tokens));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// JSON wire integers (ISSUE 6 precision satellite): `as_usize`/`as_i64`
// used to round-trip through f64, corrupting every integer above 2^53.

#[test]
fn prop_json_u64_round_trips_digit_for_digit() {
    use dsde::config::json::Json;
    property("u64 wire round-trip", 96, |rng| {
        // Bias the draw toward the boundaries where the old f64 path broke:
        // the 2^53 exactness window edge, i64::MAX, and u64::MAX.
        let v: u64 = match rng.gen_range(5) {
            0 => rng.next_u64(),
            1 => (1u64 << 53).wrapping_add(rng.gen_range(9) as u64).wrapping_sub(4),
            2 => u64::MAX - rng.gen_range(4) as u64,
            3 => (i64::MAX as u64).wrapping_add(rng.gen_range(5) as u64).wrapping_sub(2),
            _ => rng.gen_range(u32::MAX) as u64,
        };
        let text = v.to_string();
        let parsed = Json::parse(&text).map_err(|e| format!("parse {text}: {e:#}"))?;
        if parsed.as_u64() != Some(v) {
            return Err(format!("as_u64({text}) = {:?}, want {v}", parsed.as_u64()));
        }
        if parsed.to_string_compact() != text {
            return Err(format!(
                "serialize({text}) = {} — wire digits corrupted",
                parsed.to_string_compact()
            ));
        }
        // a second parse→print cycle is a fixpoint
        let again = Json::parse(&parsed.to_string_compact()).map_err(|e| format!("{e:#}"))?;
        if again.as_u64() != Some(v) {
            return Err(format!("second round-trip lost {v}"));
        }
        // usize (64-bit targets) sees the same exact value
        if parsed.as_usize() != Some(v as usize) {
            return Err(format!("as_usize({text}) = {:?}", parsed.as_usize()));
        }
        Ok(())
    });
}

#[test]
fn prop_json_i64_round_trips_digit_for_digit() {
    use dsde::config::json::Json;
    property("i64 wire round-trip", 96, |rng| {
        let v: i64 = match rng.gen_range(5) {
            0 => rng.next_u64() as i64,
            1 => i64::MIN + rng.gen_range(4) as i64,
            2 => i64::MAX - rng.gen_range(4) as i64,
            3 => -(((1u64 << 53) as i64).wrapping_add(rng.gen_range(9) as i64 - 4)),
            _ => rng.gen_range(u32::MAX) as i64 - (u32::MAX / 2) as i64,
        };
        let text = v.to_string();
        let parsed = Json::parse(&text).map_err(|e| format!("parse {text}: {e:#}"))?;
        if parsed.as_i64() != Some(v) {
            return Err(format!("as_i64({text}) = {:?}, want {v}", parsed.as_i64()));
        }
        if parsed.to_string_compact() != text {
            return Err(format!(
                "serialize({text}) = {} — wire digits corrupted",
                parsed.to_string_compact()
            ));
        }
        // From<i64> agrees with the parser on the wire form
        if Json::from(v).to_string_compact() != text {
            return Err(format!("From<i64>({v}) prints differently"));
        }
        Ok(())
    });
}

#[test]
fn prop_json_unrepresentable_integers_rejected_not_truncated() {
    use dsde::config::json::Json;
    property("unrepresentable rejected", 64, |rng| {
        // (a) float-notation integers beyond the 2^53 exactness window:
        // the f64 carries rounding error, so integer accessors must refuse.
        let beyond = (1u64 << 53) + 1 + (rng.next_u64() >> 20);
        let text = format!("{beyond}.0");
        let parsed = Json::parse(&text).map_err(|e| format!("{e:#}"))?;
        if parsed.as_u64().is_some() || parsed.as_i64().is_some() || parsed.as_usize().is_some()
        {
            return Err(format!(
                "{text} is not exactly representable but an integer accessor accepted it"
            ));
        }
        if parsed.as_f64().is_none() {
            return Err(format!("{text} must still be readable as f64"));
        }
        // (b) digit strings beyond u64::MAX: no integer accessor may
        // silently wrap or truncate.
        let overflow = format!("{}{}", u64::MAX, rng.gen_range(10));
        let parsed = Json::parse(&overflow).map_err(|e| format!("{e:#}"))?;
        if parsed.as_u64().is_some() || parsed.as_i64().is_some() {
            return Err(format!("{overflow} overflows u64 but was accepted as an integer"));
        }
        // (c) in-window float notation stays accepted: the window edge
        // itself is exact.
        let edge = 1u64 << 53;
        let parsed = Json::parse(&format!("{edge}.0")).map_err(|e| format!("{e:#}"))?;
        if parsed.as_u64() != Some(edge) {
            return Err(format!("2^53 (exact in f64) was rejected: {parsed:?}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Progressive data dropout + loss-signal curriculum (ISSUE 9): the new
// sampler policies' algebra — the staircase fraction, the pure-hash kept
// set, batch-level token conservation, and order-independent scoring.

#[test]
fn prop_pdd_fraction_monotone_and_clamped() {
    property("pdd fraction monotone + clamped", 24, |rng| {
        let f_start = rng.next_f64() * 0.5;
        let f_end = f_start + rng.next_f64() * (0.99 - f_start);
        let stages = 1 + rng.gen_range(9);
        let total = 1 + rng.gen_range(150) as u64;
        let sched = ClScheduler::with_pdd(
            &[],
            64,
            Some(PddConfig::new(f_start, f_end, stages, total)),
        )
        .map_err(|e| format!("{e:#}"))?;
        let mut prev = f64::MIN;
        for step in 0..=(total + total / 2 + 2) {
            let f = sched.state_at(step).pdd_frac;
            if !(0.0..=1.0).contains(&f) || f < f_start - 1e-9 || f > f_end + 1e-9 {
                return Err(format!(
                    "pdd_frac {f} outside [{f_start}, {f_end}] at step {step}"
                ));
            }
            if f < prev - 1e-12 {
                return Err(format!("pdd_frac not monotone at step {step}: {f} < {prev}"));
            }
            prev = f;
        }
        // and holds at f_end once the schedule is exhausted
        let f = sched.state_at(total.saturating_mul(10)).pdd_frac;
        if (f - f_end).abs() > 1e-9 {
            return Err(format!("pdd_frac {f} != f_end {f_end} past total_steps"));
        }
        Ok(())
    });
}

#[test]
fn prop_pdd_kept_set_deterministic_and_shrinks() {
    use dsde::curriculum::pdd::{is_dropped, membership_value, pdd_seed};
    property("pdd kept set deterministic + shrinking", 24, |rng| {
        let seed = pdd_seed(rng.next_u64());
        let n = 64 + rng.gen_range(192) as u64;
        // a random monotone fraction ladder starting at 0 (nothing dropped)
        let mut fracs = vec![0.0f64];
        let mut f = 0.0;
        for _ in 0..6 {
            f = (f + rng.next_f64() * 0.2).min(1.0);
            fracs.push(f);
        }
        let mut prev_kept: Vec<u64> = (0..n).collect();
        for &frac in &fracs {
            // membership is a pure function of (seed, id)
            for id in 0..n {
                if membership_value(seed, id) != membership_value(seed, id) {
                    return Err(format!("membership_value({seed:#x}, {id}) not stable"));
                }
            }
            let kept: Vec<u64> = (0..n).filter(|&id| !is_dropped(seed, id, frac)).collect();
            let again: Vec<u64> = (0..n).filter(|&id| !is_dropped(seed, id, frac)).collect();
            if kept != again {
                return Err(format!("kept set not deterministic at frac {frac}"));
            }
            // once dropped, stays dropped: kept ⊆ previous kept
            if !kept.iter().all(|id| prev_kept.binary_search(id).is_ok()) {
                return Err(format!("a dropped id came back at frac {frac}"));
            }
            prev_kept = kept;
        }
        if fracs[fracs.len() - 1] > 0.3 {
            // a different run seed must decorrelate the kept set
            let other = pdd_seed(rng.next_u64());
            if other != seed {
                let f = fracs[fracs.len() - 1];
                let differs = (0..n).any(|id| is_dropped(seed, id, f) != is_dropped(other, id, f));
                if !differs {
                    return Err("distinct seeds produced identical kept sets".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pdd_token_conservation_under_ltd() {
    use dsde::curriculum::pdd::is_dropped;
    let c = Corpus::generate(CorpusConfig { n_docs: 250, seed: 47, ..Default::default() });
    let t = Tokenizer::from_corpus(&c);
    let ds = Arc::new(GptDataset::build(&c, &t, 64));
    let n = ds.n_samples();
    property("pdd token conservation (+LTD)", 12, |rng| {
        let batch = 8usize;
        let seq = [16usize, 32, 64][rng.gen_range(3) as usize];
        let pdd_seed = rng.next_u64();
        let frac = rng.next_f64() * 0.9;
        let mut loader = GptLoader::new(
            ds.clone(),
            Box::new(UniformSampler::new(n, rng.next_u64())),
            batch,
        )
        .with_pdd_seed(pdd_seed);
        let core: LoaderCore = loader.core();
        let transform = if seq < 64 { SeqTransform::Truncate } else { SeqTransform::None };
        let st = ClState { seq, transform, pool_pct: 1.0, pdd_frac: frac };

        let ltd = LtdConfig::mslg(1 + rng.gen_range(48) as usize, 40);
        let mut acct = TokenAccountant::new(4);
        let mut expect_physical = 0u64;
        let mut expect_pdd = 0u64;
        for step in 0..6u64 {
            let plan = loader.plan_batch(seq, &st);
            // the plan's dropped rows are exactly the pure-hash membership
            // verdicts on the drawn ids (one id per row here)
            for (r, &id) in plan.ids.iter().enumerate() {
                let planned = plan.dropped.binary_search(&(r as u32)).is_ok();
                if planned != is_dropped(pdd_seed, id as u64, frac) {
                    return Err(format!("row {r} (id {id}) disagrees with is_dropped"));
                }
            }
            let b = match core.materialize(&BatchPlan::Lm(plan.clone()), None) {
                dsde::curriculum::AnyBatch::Lm(b) => b,
                _ => return Err("wrong batch kind".into()),
            };
            if b.dropped_rows != plan.dropped {
                return Err("materialized dropped_rows differ from the plan".into());
            }
            // conservation: trained + dropped == physical, exactly
            let physical = (b.rows * b.seq) as u64;
            let dropped = (b.dropped_rows.len() * b.seq) as u64;
            if b.data_tokens + dropped != physical {
                return Err(format!(
                    "data_tokens {} + dropped {dropped} != physical {physical}",
                    b.data_tokens
                ));
            }
            // dropped rows carry an all-zero loss mask; kept rows don't
            for r in 0..b.rows {
                let row = &b.loss_mask[r * b.seq..(r + 1) * b.seq];
                let zeroed = row.iter().all(|&m| m == 0.0);
                let is_dropped_row = b.dropped_rows.binary_search(&(r as u32)).is_ok();
                if is_dropped_row != zeroed {
                    return Err(format!(
                        "row {r}: dropped={is_dropped_row} but mask zeroed={zeroed}"
                    ));
                }
            }
            // and the accountant keeps the same books when LTD composes in
            let kept = kept_len(&ltd, step, seq);
            acct.record(b.rows, b.seq, kept, 2);
            acct.record_pdd_dropped(dropped);
            expect_physical += physical;
            expect_pdd += dropped;
        }
        if acct.trained_data_tokens() + acct.pdd_dropped_tokens() != expect_physical {
            return Err(format!(
                "accountant: trained {} + pdd-dropped {} != physical {expect_physical}",
                acct.trained_data_tokens(),
                acct.pdd_dropped_tokens()
            ));
        }
        if acct.pdd_dropped_tokens() != expect_pdd {
            return Err(format!(
                "accountant pdd-dropped {} != per-batch sum {expect_pdd}",
                acct.pdd_dropped_tokens()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_loss_signal_scores_permutation_stable() {
    use dsde::ltd::LossSignalTracker;
    property("loss-signal scores permutation-stable", 16, |rng| {
        let n_ids = 8 + rng.gen_range(24) as usize;
        // Dyadic losses (k/8) make every f64 sum exact, so reordering the
        // update stream must reproduce bit-identical scores — the property
        // the difficulty ordering's determinism rests on.
        let updates: Vec<(Vec<i32>, f64)> = (0..20)
            .map(|_| {
                let toks: Vec<i32> = (0..4 + rng.gen_range(8) as usize)
                    .map(|_| rng.gen_range(n_ids as u32 + 4) as i32) // some out of range
                    .collect();
                (toks, rng.gen_range(64) as f64 / 8.0)
            })
            .collect();
        let mut order: Vec<usize> = (0..updates.len()).collect();
        // Fisher–Yates off the property rng
        for i in (1..order.len()).rev() {
            order.swap(i, rng.gen_range(i as u32 + 1) as usize);
        }
        let mut a = LossSignalTracker::new(n_ids);
        for (toks, loss) in &updates {
            a.update(toks, *loss);
        }
        a.publish();
        let mut b = LossSignalTracker::new(n_ids);
        for &i in &order {
            let (toks, loss) = &updates[i];
            b.update(toks, *loss);
        }
        b.publish();
        let (sa, sb) = (a.scores(), b.scores());
        if sa.len() != n_ids || sb.len() != n_ids {
            return Err("scores() length != n_ids".into());
        }
        for (i, (x, y)) in sa.iter().zip(&sb).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("score[{i}] depends on update order: {x} vs {y}"));
            }
        }
        // unseen ids score 0 (never NaN), seen ids are the exact mean
        for (i, s) in sa.iter().enumerate() {
            if !s.is_finite() {
                return Err(format!("score[{i}] = {s} is not finite"));
            }
        }
        // publish() is a boundary cut: further updates don't move scores
        a.update(&[0, 1, 2], 7.5);
        if a.scores() != sa {
            return Err("scores moved before the next publish()".into());
        }
        Ok(())
    });
}
