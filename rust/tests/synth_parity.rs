//! Cross-language grid parity: the in-process synthesis
//! (`runtime::synth`) must agree byte for byte with the independent
//! Python reference generator (`python/compile/gen_stub_artifacts.py`)
//! on the full 182-point legacy grid and on `manifest.json`.
//!
//! History: before the committed `.hlo` grid was deleted, this test
//! byte-compared the Rust synthesis against every on-disk artifact (see
//! the commit introducing `runtime/synth.rs`) — that is what proved the
//! port. The Python generator now serves as the independent reference,
//! and CI additionally runs the comparison in the other direction
//! (`dsde synth --out` ↔ `gen_stub_artifacts.py --check`).

use dsde::runtime::Registry;
use std::path::{Path, PathBuf};
use std::process::Command;

/// The committed manifest is an *emitted* spec: Rust emission must
/// reproduce it byte for byte (this is also what the legacy Python
/// generator wrote, unchanged by the port).
#[test]
fn manifest_emission_is_byte_identical_to_committed() {
    let registry = Registry::builtin().unwrap();
    let legacy = std::fs::read_to_string("artifacts/manifest.json").unwrap();
    assert_eq!(registry.manifest_text().unwrap(), legacy);
}

#[test]
fn grid_enumeration_is_stable() {
    let registry = Registry::builtin().unwrap();
    assert_eq!(registry.grid.len(), 182);
    // moe is a first-class family: its ltd/bypass train + grad variant set
    // must mirror gpt's (same seq/keep/shard-width points) so the dp and
    // exact-dispatch suites can run identical cases on both.
    let suffixes = |fam: &str| {
        let mut v: Vec<String> = registry
            .grid
            .keys()
            .filter(|n| n.starts_with(&format!("{fam}_")))
            .map(|n| n[fam.len()..].to_string())
            .collect();
        v.sort();
        v
    };
    assert_eq!(suffixes("moe"), suffixes("gpt"), "moe grid must mirror gpt");
    for (name, info) in &registry.grid {
        assert_eq!(name, &info.name);
        // every grid point synthesizes and round-trips through the name parser
        let text = registry.module_text(info).unwrap();
        assert!(text.starts_with("# dsde surrogate HLO module"));
        let reparsed = registry.artifact(name).unwrap();
        assert_eq!(reparsed.inputs.len(), info.inputs.len());
        assert_eq!(reparsed.outputs.len(), info.outputs.len());
    }
}

/// Full byte comparison against the Python reference generator. Skips
/// (with a note) when `python3` is unavailable; CI always runs it.
#[test]
fn synthesis_matches_python_reference_generator() {
    let script = Path::new("../python/compile/gen_stub_artifacts.py");
    assert!(script.exists(), "cross-check harness missing");
    let out_dir: PathBuf =
        std::env::temp_dir().join(format!("dsde_py_grid_{}", std::process::id()));
    let status = Command::new("python3")
        .arg(script)
        .arg("--out")
        .arg(&out_dir)
        .status();
    let status = match status {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping python cross-check (python3 unavailable: {e})");
            return;
        }
    };
    assert!(status.success(), "reference generator failed");

    let registry = Registry::builtin().unwrap();
    let mut compared = 0usize;
    for entry in std::fs::read_dir(&out_dir).unwrap() {
        let path = entry.unwrap().path();
        let file = path.file_name().unwrap().to_str().unwrap().to_string();
        let reference = std::fs::read_to_string(&path).unwrap();
        let synthesized = if file == "manifest.json" {
            registry.manifest_text().unwrap()
        } else if let Some(name) = file.strip_suffix(".hlo") {
            let info = registry
                .grid
                .get(name)
                .unwrap_or_else(|| panic!("python emitted '{name}', not on the Rust grid"));
            registry.module_text(info).unwrap()
        } else {
            continue;
        };
        assert_eq!(synthesized, reference, "'{file}' diverges from the Python reference");
        compared += 1;
    }
    std::fs::remove_dir_all(&out_dir).ok();
    assert_eq!(
        compared,
        registry.grid.len() + 1,
        "expected every grid point + manifest to be compared"
    );
}
