//! Grid parity: the in-process synthesis (`runtime::synth`) must
//! reproduce the legacy Python-generated artifact set byte for byte —
//! every surrogate module of the 172-point grid and `manifest.json`
//! itself. This is the proof obligation that allowed deleting the
//! committed `.hlo` grid.

use dsde::runtime::Registry;

/// Every legacy `.hlo` on disk must equal the Rust synthesis, and every
/// grid point must have an on-disk counterpart (no drift either way).
#[test]
fn synthesis_is_byte_identical_to_legacy_artifacts() {
    let dir = std::path::Path::new("artifacts");
    let registry = Registry::builtin().unwrap();
    let mut on_disk = 0usize;
    for entry in std::fs::read_dir(dir).expect("artifacts dir present") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("hlo") {
            continue;
        }
        on_disk += 1;
        let name = path.file_stem().unwrap().to_str().unwrap().to_string();
        let legacy = std::fs::read_to_string(&path).unwrap();
        let info = registry
            .grid
            .get(&name)
            .unwrap_or_else(|| panic!("on-disk artifact '{name}' missing from the grid"));
        let synthesized = registry.module_text(info).unwrap();
        assert_eq!(
            synthesized, legacy,
            "synthesized module for '{name}' differs from the legacy artifact"
        );
    }
    assert_eq!(
        on_disk,
        registry.grid.len(),
        "grid enumeration and on-disk artifact set must match 1:1"
    );
}

#[test]
fn manifest_emission_is_byte_identical() {
    let registry = Registry::builtin().unwrap();
    let legacy = std::fs::read_to_string("artifacts/manifest.json").unwrap();
    assert_eq!(registry.manifest_text().unwrap(), legacy);
}
