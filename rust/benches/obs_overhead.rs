//! obs_overhead — the observability gate (ISSUE 10 tentpole).
//!
//! Two enforcing checks on the span recorder:
//!
//! 1. **Overhead**: steps/sec on a tiny-step composed GPT config with
//!    tracing off vs tracing on (default ring). The recorder must cost
//!    under 3% of throughput (best-of-trials on both sides to shave
//!    scheduler noise).
//! 2. **Bit-identity**: tracing must be a pure timing side-channel —
//!    `state_hash`, per-step f32 losses and the dispatch histogram must
//!    be byte-identical with tracing off, on at the default ring, and on
//!    at a tiny 64-event ring (constant overflow → drop-oldest churn).
//!
//! Any overhead blow-past or oracle drift exits non-zero so the CI
//! bench-smoke job goes red. Results land in `BENCH_HISTORY.json` under
//! `obs_overhead` when `DSDE_BENCH_HISTORY=1`; `DSDE_BENCH_QUICK=1`
//! shrinks everything for the smoke job.

use dsde::bench::{history_append, scaled, Table};
use dsde::config::json::Json;
use dsde::config::schema::*;
use dsde::train::{RunResult, TrainEnv};

const MAX_OVERHEAD: f64 = 0.03;

fn tiny_case(steps: u64) -> RunConfig {
    let mut c = RunConfig::baseline("gpt", steps, 3e-3);
    c.label = "obs-overhead".into();
    c.seed = 4242;
    c.eval_every = steps; // keep the loop hot: evaluate only at the end
    c.curriculum = vec![ClConfig::new(
        Metric::SeqTru,
        Bound::Value(8.0),
        Bound::Value(64.0),
        (steps as f64 * 0.6) as u64,
    )];
    c.routing = Routing::RandomLtd(LtdConfig::mslg(16, steps));
    c.pipeline = PipelineConfig { prefetch_depth: 3, n_loader_workers: 2 };
    c
}

/// Run the case `trials` times under the current recorder mode, keeping
/// the fastest wall clock (the result is bit-identical across trials, so
/// any of them can stand in for the oracle comparison).
fn best_of(env: &TrainEnv, steps: u64, trials: usize) -> dsde::Result<(RunResult, f64)> {
    let mut best: Option<(RunResult, f64)> = None;
    for _ in 0..trials {
        dsde::obs::reset();
        let r = env.run(tiny_case(steps))?;
        let wall = r.wall_secs;
        if best.as_ref().map(|(_, w)| wall < *w).unwrap_or(true) {
            best = Some((r, wall));
        }
    }
    Ok(best.expect("at least one trial"))
}

fn identical(a: &RunResult, b: &RunResult) -> bool {
    a.state_hash == b.state_hash && a.step_losses == b.step_losses && a.dispatch == b.dispatch
}

fn main() -> dsde::Result<()> {
    let steps = scaled(200, 12);
    let docs = scaled(400, 200) as usize;
    let trials = scaled(3, 2) as usize;
    eprintln!("== obs_overhead: recorder cost + tracing bit-identity ==");
    let env = TrainEnv::new(docs, 7)?;

    // ---- tracing off: the reference -------------------------------------
    dsde::obs::set_enabled(false);
    dsde::obs::set_ring_capacity(dsde::obs::DEFAULT_RING_CAP);
    let (r_off, wall_off) = best_of(&env, steps, trials)?;

    // ---- tracing on, default ring ---------------------------------------
    dsde::obs::set_enabled(true);
    let (r_on, wall_on) = best_of(&env, steps, trials)?;

    // ---- tracing on, tiny ring (constant drop-oldest churn) -------------
    dsde::obs::set_ring_capacity(64);
    let (r_small, wall_small) = best_of(&env, steps, trials)?;
    let dropped = dsde::obs::dropped_events();

    dsde::obs::set_enabled(false);
    dsde::obs::reset();
    dsde::obs::set_ring_capacity(dsde::obs::DEFAULT_RING_CAP);

    let off_sps = steps as f64 / wall_off.max(1e-9);
    let on_sps = steps as f64 / wall_on.max(1e-9);
    let small_sps = steps as f64 / wall_small.max(1e-9);
    let overhead = (off_sps - on_sps) / off_sps.max(1e-9);

    let mut t = Table::new(&["mode", "steps", "wall s", "steps/s"]);
    for (name, wall, sps) in [
        ("tracing off", wall_off, off_sps),
        ("tracing on", wall_on, on_sps),
        ("tracing on, ring 64", wall_small, small_sps),
    ] {
        t.row(vec![
            name.into(),
            steps.to_string(),
            format!("{wall:.3}"),
            format!("{sps:.1}"),
        ]);
    }
    println!("\nrecorder overhead (composed GPT, {steps} tiny steps, best of {trials}):");
    t.print();
    t.save_csv("obs_overhead")?;
    println!(
        "overhead: {:.2}% (gate {:.0}%); ring-64 run dropped {dropped} event(s)",
        overhead * 100.0,
        MAX_OVERHEAD * 100.0
    );

    let overhead_ok = overhead < MAX_OVERHEAD;
    let identity_ok = identical(&r_off, &r_on) && identical(&r_off, &r_small);
    let drop_ok = dropped > 0; // a 64-event ring MUST overflow on this run

    history_append(
        "obs_overhead",
        &Json::obj(vec![
            ("steps", (steps as usize).into()),
            ("off_steps_per_s", off_sps.into()),
            ("on_steps_per_s", on_sps.into()),
            ("small_ring_steps_per_s", small_sps.into()),
            ("overhead_frac", overhead.into()),
            ("dropped_small_ring", (dropped as usize).into()),
            ("bit_identical", identity_ok.into()),
        ]),
    )?;

    println!(
        "\nshape check:\n  [{}] recorder overhead under {:.0}% of steps/sec\n  \
         [{}] tracing off/on/ring-64 bit-identical (state hash, losses, dispatch)\n  \
         [{}] tiny ring actually overflowed (drop-oldest path exercised)",
        if overhead_ok { "PASS" } else { "FAIL" },
        MAX_OVERHEAD * 100.0,
        if identity_ok { "PASS" } else { "FAIL" },
        if drop_ok { "PASS" } else { "FAIL" }
    );
    if !(overhead_ok && identity_ok && drop_ok) {
        // Enforcing, not advisory: tracing must stay a free-when-off,
        // cheap-when-on pure side-channel.
        std::process::exit(1);
    }
    Ok(())
}
