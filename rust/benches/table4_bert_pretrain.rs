//! Tab. 4 reproduction — BERT-large pretraining grid (cases 1–15).
//!
//! Paper shape: CL metrics ≥ baseline at 100% data; random-LTD achieves
//! the best quality and keeps it even at 2x less data (case 14 vs 1),
//! surpassing TokenBypass's 1.33x; composed case 15 recovers baseline
//! quality at 50% data with ~1.8x time saving (LTD adds per-step overhead,
//! so time saving < data saving — we report both columns).

use dsde::bench::{scaled, Table};
use dsde::exp::cases::table4_bert;
use dsde::exp::{run_cases, table_headers, table_row};
use dsde::sim::CostModel;
use dsde::train::TrainEnv;

fn main() -> dsde::Result<()> {
    let full_steps = scaled(80, 16);
    let n_docs = scaled(800, 300) as usize;
    eprintln!("== Tab. 4: BERT pretraining grid (full budget {full_steps} steps) ==");
    let env = TrainEnv::new(n_docs, 7)?;
    let fam = env.rt.registry.family("bert")?.clone();

    let results = run_cases(&env, table4_bert(full_steps, fam.max_seq, 1234))?;
    let baseline = &results[0];
    let cost = CostModel::new(baseline.compute_tokens, baseline.wall_secs);

    let mut table = Table::new(&table_headers());
    for r in &results {
        table.row(table_row(r, &cost, baseline.final_eval_loss));
    }
    println!("\nTab. 4 (reproduced; quality = inverse-MLM-loss % of baseline — the");
    println!("paper's GLUE column is proxied per DESIGN.md §Substitutions)");
    table.print();
    let csv = table.save_csv("table4_bert_pretrain")?;
    eprintln!("csv -> {}", csv.display());

    let loss = |i: usize| results[i].final_eval_loss;
    // paper: rLTD time saving < data saving (token-drop step overhead)
    let rltd50 = &results[13];
    let base50 = &results[11];
    let data_saving = baseline.compute_tokens / rltd50.compute_tokens;
    let time_saving = baseline.wall_secs / rltd50.wall_secs;
    let checks: Vec<(String, bool)> = vec![
        ("CL_seqtru_voc(5) beats baseline(1)".into(), loss(4) < loss(0)),
        ("random-LTD(7) among the best at 100%".into(), loss(6) < loss(0)),
        ("baseline@50%(12) worse than baseline(1)".into(), loss(11) > loss(0)),
        ("rLTD@50%(14) recovers vs baseline@50%(12)".into(), loss(13) < base50.final_eval_loss),
        ("composed@50%(15) recovers vs baseline@50%(12)".into(), loss(14) < base50.final_eval_loss),
        (
            format!("data saving ({data_saving:.2}x) ≥ time saving ({time_saving:.2}x)"),
            data_saving >= time_saving * 0.95,
        ),
    ];
    println!("\nshape checks:");
    for (name, ok) in &checks {
        println!("  [{}] {name}", if *ok { "PASS" } else { "FAIL" });
    }
    Ok(())
}
