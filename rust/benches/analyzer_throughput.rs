//! §3.1 analyzer claim — map-reduce difficulty-indexing throughput.
//!
//! The paper indexes the GPT-3 Pile metric in 3h and the BERT metric in
//! 80h on one 40-thread CPU node. This bench measures our analyzer's
//! samples/s versus worker count and the map/reduce split, plus the
//! mmap index save/open round-trip cost.

use dsde::analysis::analyzer::AnalyzerConfig;
use dsde::analysis::metrics;
use dsde::bench::{scaled, Table};
use dsde::data::corpus::{Corpus, CorpusConfig};
use dsde::data::dataset::GptDataset;
use dsde::data::tokenizer::Tokenizer;

fn main() -> dsde::Result<()> {
    let n_docs = scaled(10_000, 2_000) as usize;
    eprintln!("== analyzer throughput ({n_docs} docs) ==");
    let corpus = Corpus::generate(CorpusConfig { n_docs, ..Default::default() });
    let tok = Tokenizer::from_corpus(&corpus);
    let ds = GptDataset::build(&corpus, &tok, 64);
    eprintln!("dataset: {} samples, {} tokens", ds.n_samples(), ds.stream.len());

    let mut table = Table::new(&[
        "workers",
        "samples/s",
        "map s",
        "reduce s",
        "reduce %",
    ]);
    let mut order_ref: Option<Vec<u32>> = None;
    for workers in [1usize, 2, 4, 8] {
        let cfg = AnalyzerConfig { n_workers: workers, shard_size: 2048 };
        let (idx, rep) = metrics::gpt_voc(&ds, &tok, &cfg);
        let total = rep.map_secs + rep.reduce_secs;
        table.row(vec![
            workers.to_string(),
            format!("{:.0}", rep.samples_per_sec()),
            format!("{:.3}", rep.map_secs),
            format!("{:.3}", rep.reduce_secs),
            format!("{:.1}%", rep.reduce_secs / total * 100.0),
        ]);
        match &order_ref {
            None => order_ref = Some(idx.order().to_vec()),
            Some(r) => assert_eq!(r.as_slice(), idx.order(), "worker count changed result"),
        }
    }
    println!("\nanalyzer scaling (gpt voc metric)");
    table.print();
    table.save_csv("analyzer_throughput")?;

    // index save/open round-trip
    let (idx, _) = metrics::gpt_voc(&ds, &tok, &AnalyzerConfig::default());
    let path = std::env::temp_dir().join("dsde_bench_index.bin");
    let t0 = std::time::Instant::now();
    idx.save(&path)?;
    let save_s = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let opened = dsde::data::index::DifficultyIndex::open(&path)?;
    let open_s = t1.elapsed().as_secs_f64();
    assert_eq!(opened.order(), idx.order());
    println!(
        "\nindex file: {} samples, save {:.1}ms, open {:.3}ms, {} bytes",
        idx.len(),
        save_s * 1e3,
        open_s * 1e3,
        std::fs::metadata(&path)?.len()
    );
    let _ = std::fs::remove_file(&path);

    // paper-scale extrapolation: samples/s → hours for 173M samples
    let (_, rep) = metrics::gpt_voc(&ds, &tok, &AnalyzerConfig { n_workers: 4, shard_size: 2048 });
    let hours = 173e6 / rep.samples_per_sec() / 3600.0;
    println!(
        "extrapolation: at {:.0} samples/s, the paper's 173M GPT samples would take {:.1}h \
         on this node (paper: 3h on 40 threads; our samples are 32x shorter)",
        rep.samples_per_sec(),
        hours
    );
    Ok(())
}
