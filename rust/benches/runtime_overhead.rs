//! L3 hot-path microbenchmarks (§Perf): where does a training step's time
//! go, and is the coordinator ever the bottleneck?
//!
//! Measures, per layer of the L3 step loop:
//!  * PJRT executable compile time (one-time, amortized by the registry cache)
//!  * batch preparation (loader) cost
//!  * literal creation + argument assembly cost
//!  * raw execute cost per variant (full vs LTD vs short-seq buckets)
//!  * random-LTD keep-index generation cost
//!  * prefetch pipeline overlap gain
//!  * state round-trip (tuple decompose) share

use dsde::bench::{scaled, time_it, Table};
use dsde::config::schema::{PipelineConfig, RunConfig};
use dsde::curriculum::scheduler::{ClState, SeqTransform};
use dsde::curriculum::{GptLoader, UniformSampler};
use dsde::data::corpus::{Corpus, CorpusConfig};
use dsde::data::dataset::GptDataset;
use dsde::data::tokenizer::Tokenizer;
use dsde::ltd::RandomDropper;
use dsde::runtime::{lit_f32, lit_i32, scalar_f32, scalar_u32, Runtime};
use dsde::train::{Prefetcher, TrainEnv};
use std::sync::Arc;

fn main() -> dsde::Result<()> {
    let iters = scaled(20, 5) as usize;
    eprintln!("== runtime overhead breakdown ({iters} iters/measurement) ==");
    let rt = Runtime::open_default()?;
    let fam = rt.registry.family("gpt")?.clone();

    // ---- compile times (cold JIT specialization; includes an off-grid
    // point the static artifact set never carried)
    let mut compile_table = Table::new(&["artifact", "compile ms", "module B"]);
    for name in [
        "gpt_train_s64_full",
        "gpt_train_s64_ltd32",
        "gpt_train_s8_full",
        "gpt_eval_s64",
        "gpt_train_s20_ltd7", // off-grid: synthesized on demand
    ] {
        let step = rt.step(name)?;
        let size = rt.registry.module_text(&step.info)?.len();
        compile_table.row(vec![
            name.to_string(),
            format!("{:.3}", step.compile_secs * 1e3),
            size.to_string(),
        ]);
    }
    println!("\ncold synthesize+compile cost (LRU-cached afterwards):");
    compile_table.print();

    // ---- data plumbing
    let corpus = Corpus::generate(CorpusConfig { n_docs: 500, ..Default::default() });
    let tok = Tokenizer::from_corpus(&corpus);
    let ds = Arc::new(GptDataset::build(&corpus, &tok, fam.max_seq));
    let n = ds.n_samples();
    let mut loader = GptLoader::new(ds.clone(), Box::new(UniformSampler::new(n, 1)), fam.batch);
    let st = ClState { seq: 64, transform: SeqTransform::None, pool_pct: 1.0, pdd_frac: 0.0 };
    let batch_prep = time_it(3, iters, || {
        let b = loader.next_batch(64, &st);
        std::hint::black_box(b.tokens.len());
    });

    let b = loader.next_batch(64, &st);
    let dims = [fam.batch, 64usize];
    let literal_mk = time_it(3, iters, || {
        let t = lit_i32(&b.tokens, &dims).unwrap();
        let g = lit_i32(&b.targets, &dims).unwrap();
        let m = lit_f32(&b.loss_mask, &dims).unwrap();
        std::hint::black_box((t.size_bytes(), g.size_bytes(), m.size_bytes()));
    });

    let mut dropper = RandomDropper::new(5);
    let drop_gen = time_it(3, iters, || {
        let idx = dropper.layerwise(fam.n_middle_layers, 64, 32);
        std::hint::black_box(idx.len());
    });

    // ---- execute per variant
    let init = rt.step("gpt_init")?;
    let state = init.execute(&[scalar_u32(0)])?;
    let n_state = state.len();
    let mut exec_table = Table::new(&["variant", "execute ms", "std ms"]);
    for name in ["gpt_train_s64_full", "gpt_train_s64_ltd32", "gpt_train_s32_full", "gpt_train_s8_full", "gpt_eval_s64"] {
        let step = rt.step(name)?;
        let info = &step.info;
        let seq = info.seq;
        let is_eval = info.kind == "eval";
        let tokens: Vec<i32> = (0..fam.batch * seq).map(|i| 6 + (i as i32 % 500)).collect();
        let mask = vec![1.0f32; fam.batch * seq];
        let dims = [fam.batch, seq];
        let mut extra: Vec<xla::Literal> = Vec::new();
        if !is_eval {
            extra.push(scalar_f32(1.0));
            extra.push(scalar_f32(1e-3));
        }
        extra.push(lit_i32(&tokens, &dims)?);
        extra.push(lit_i32(&tokens, &dims)?);
        extra.push(lit_f32(&mask, &dims)?);
        if info.mode == dsde::runtime::Mode::Ltd {
            let idx = dropper.layerwise(fam.n_middle_layers, seq, info.keep).to_vec();
            extra.push(lit_i32(&idx, &[fam.n_middle_layers, info.keep])?);
        }
        let state_slice = if is_eval { &state[..fam.n_params] } else { &state[..] };
        let stats = time_it(2, iters, || {
            let args: Vec<&xla::Literal> = state_slice.iter().chain(extra.iter()).collect();
            let out = step.execute_refs(&args).unwrap();
            std::hint::black_box(out.len());
        });
        exec_table.row(vec![
            name.to_string(),
            format!("{:.2}", stats.mean * 1e3),
            format!("{:.2}", stats.std * 1e3),
        ]);
    }
    println!("\nexecute cost per variant:");
    exec_table.print();

    // ---- state round-trip share: execute vs output-tuple handling is
    // already included above; measure the literal sizes instead.
    let state_bytes: usize = state.iter().map(|l| l.size_bytes()).sum();
    println!(
        "\nstate: {} literals, {:.2} MiB total (host round-trip per step)",
        n_state,
        state_bytes as f64 / (1024.0 * 1024.0)
    );

    // ---- prefetch overlap
    let ds2 = ds.clone();
    let batch_ms = batch_prep.mean * 1e3;
    let pf = Prefetcher::new(iters as u64, 4, move |i| {
        let mut loader =
            GptLoader::new(ds2.clone(), Box::new(UniformSampler::new(n, i)), 8);
        loader.next_batch(
            64,
            &ClState { seq: 64, transform: SeqTransform::None, pool_pct: 1.0, pdd_frac: 0.0 },
        )
    });
    let consume = time_it(0, iters, || {
        let b = pf.next().unwrap();
        std::hint::black_box(b.tokens.len());
    });

    let mut t = Table::new(&["component", "mean ms", "share of 64-seq step"]);
    let step_ms = {
        let full = rt.step("gpt_train_s64_full")?;
        let tokens: Vec<i32> = (0..fam.batch * 64).map(|i| 6 + (i as i32 % 500)).collect();
        let mask = vec![1.0f32; fam.batch * 64];
        let extra = vec![
            scalar_f32(1.0),
            scalar_f32(1e-3),
            lit_i32(&tokens, &[fam.batch, 64])?,
            lit_i32(&tokens, &[fam.batch, 64])?,
            lit_f32(&mask, &[fam.batch, 64])?,
        ];
        time_it(2, iters, || {
            let args: Vec<&xla::Literal> = state.iter().chain(extra.iter()).collect();
            std::hint::black_box(full.execute_refs(&args).unwrap().len());
        })
        .mean
            * 1e3
    };
    for (name, ms) in [
        ("batch prep (loader)", batch_ms),
        ("literal creation", literal_mk.mean * 1e3),
        ("LTD index generation", drop_gen.mean * 1e3),
        ("prefetched batch recv", consume.mean * 1e3),
        ("execute (s64 full)", step_ms),
    ] {
        t.row(vec![
            name.to_string(),
            format!("{ms:.3}"),
            format!("{:.1}%", ms / step_ms * 100.0),
        ]);
    }
    println!("\nhot-path breakdown:");
    t.print();
    t.save_csv("runtime_overhead")?;

    let coordinator_ms = batch_ms + literal_mk.mean * 1e3 + drop_gen.mean * 1e3;
    println!(
        "\nshape check:\n  [{}] coordinator overhead ({coordinator_ms:.2}ms) ≤ 5% of execute ({step_ms:.2}ms)",
        if coordinator_ms <= step_ms * 0.05 { "PASS" } else { "FAIL" }
    );

    // ---- async batch pipeline: loader stall with prefetch off vs on.
    // BERT is the heaviest batch builder (MLM masking), so it shows the
    // largest synchronous stall; the async pipeline must hide most of it.
    let steps = scaled(80, 24);
    let env = TrainEnv::new(400, 7)?;
    let case = |label: &str, pipeline: PipelineConfig| {
        let mut c = RunConfig::baseline("bert", steps, 3e-3);
        c.label = label.to_string();
        c.pipeline = pipeline;
        c
    };
    let sync = env.run(case("sync-loader", PipelineConfig::disabled()))?;
    let pre = env.run(case(
        "prefetch-d4-w4",
        PipelineConfig { prefetch_depth: 4, n_loader_workers: 4 },
    ))?;
    let mut pt = Table::new(&["loader mode", "build ms", "stall ms", "hidden"]);
    for r in [&sync, &pre] {
        pt.row(vec![
            r.label.clone(),
            format!("{:.2}", r.loader_build_secs * 1e3),
            format!("{:.2}", r.loader_stall_secs * 1e3),
            format!("{:.0}%", r.loader_hidden_fraction() * 100.0),
        ]);
    }
    println!("\nasync pipeline overlap ({steps} bert steps, depth 4, 4 workers):");
    pt.print();
    pt.save_csv("runtime_overhead_prefetch")?;
    let hidden = pre.loader_hidden_fraction();
    println!(
        "  [{}] prefetch hides >50% of batch-construction time (hidden {:.0}%, \
         sync stall {:.2}ms -> async stall {:.2}ms)",
        if hidden > 0.5 { "PASS" } else { "FAIL" },
        hidden * 100.0,
        sync.loader_stall_secs * 1e3,
        pre.loader_stall_secs * 1e3
    );

    // ---- JIT specialization cache: cold-compile volume, hit rate, and
    // prewarm effectiveness. Exact dispatch on the composed GPT schedule
    // is the most specialization-heavy workload we have (every curriculum
    // seq/keep point compiles its own program); running it with the
    // background prewarmer off vs on shows how much compile time lands on
    // the step loop ("stall") vs hides behind it.
    let jit_steps = scaled(80, 24);
    let base = dsde::exp::cases::exact_dispatch_cases(jit_steps, fam.max_seq, 7)
        .into_iter()
        .next()
        .expect("exact case");
    env.rt.clear_cache();
    let r_off = env.run({
        let mut c = base.clone();
        c.prewarm = false;
        c.label = "prewarm-off".into();
        c
    })?;
    env.rt.clear_cache();
    let r_on = env.run({
        let mut c = base;
        c.label = "prewarm-on".into();
        c
    })?;
    let mut jt = Table::new(&[
        "prewarm", "inline compiles", "prewarmed", "compile stall ms", "hit rate",
    ]);
    for r in [&r_off, &r_on] {
        let lookups = (r.cache_hits + r.cache_misses).max(1);
        jt.row(vec![
            r.label.clone(),
            r.cache_misses.to_string(),
            r.prewarmed_compiles.to_string(),
            format!("{:.3}", r.compile_stall_secs * 1e3),
            format!("{:.1}%", r.cache_hits as f64 / lookups as f64 * 100.0),
        ]);
    }
    println!("\nJIT specialization cache ({jit_steps} exact-dispatch gpt steps):");
    jt.print();
    jt.save_csv("runtime_overhead_jit")?;
    let stats = env.rt.cache_stats();
    println!(
        "  cumulative: {} hits / {} misses ({:.0}% hit rate), {} prewarmed, \
         {:.1}ms inline + {:.1}ms background compile",
        stats.hits,
        stats.misses,
        stats.hit_rate() * 100.0,
        stats.prewarmed,
        stats.inline_compile_secs * 1e3,
        stats.prewarm_compile_secs * 1e3
    );
    println!(
        "  [{}] prewarm keeps compile off the step loop (stall {:.3}ms with prewarm \
         vs {:.3}ms without)",
        if r_on.compile_stall_secs <= r_off.compile_stall_secs + 0.005 {
            "PASS"
        } else {
            "FAIL"
        },
        r_on.compile_stall_secs * 1e3,
        r_off.compile_stall_secs * 1e3
    );
    Ok(())
}
