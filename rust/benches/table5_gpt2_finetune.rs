//! Tab. 5 reproduction — GPT-2 PTB-finetuning-style study.
//!
//! The paper finetunes GPT-2 350M on PTB with a 16-combination sweep per
//! technique and reports: best ppl at seed 1234, how many of the 16
//! combinations surpass the baseline (hyperparameter robustness), and the
//! 5-seed median±std for the winners. We mirror the protocol on a small
//! held-out "finetune" corpus: seqres is expected to be the best CL metric
//! (small batches make seqtru's token reduction undesirable — §A.3).

use dsde::bench::{quick_mode, Table};
use dsde::config::schema::*;
use dsde::exp::run_cases;
use dsde::train::TrainEnv;

fn sweep_cl(steps: u64, max_seq: usize, metric: Metric, seed: u64) -> Vec<RunConfig> {
    // 16 combos: d_s ∈ {S/8, S/4, S/2, S} × T_c ∈ {10,30,50,70}% (paper §A.3)
    let d_starts = [max_seq / 8, max_seq / 4, max_seq / 2, max_seq];
    let fracs = [0.1, 0.3, 0.5, 0.7];
    let mut out = Vec::new();
    for &d in &d_starts {
        for &f in &fracs {
            let mut c = RunConfig::baseline("gpt", steps, 3e-3);
            c.seed = seed;
            c.label = format!("CL_{}_d{}_t{:.0}", metric.name(), d, f * 100.0);
            c.curriculum.push(ClConfig::new(
                metric,
                Bound::Value(d as f64),
                Bound::Value(max_seq as f64),
                ((steps as f64 * f) as u64).max(1),
            ));
            out.push(c);
        }
    }
    out
}

fn sweep_ltd(steps: u64, max_seq: usize, seed: u64) -> Vec<RunConfig> {
    let r_starts = [max_seq / 8, max_seq / 4, max_seq / 2, 3 * max_seq / 4];
    let fracs = [0.1, 0.3, 0.5, 0.7];
    let mut out = Vec::new();
    for &r in &r_starts {
        for &f in &fracs {
            let mut c = RunConfig::baseline("gpt", steps, 3e-3);
            c.seed = seed;
            c.label = format!("rLTD_r{}_t{:.0}", r, f * 100.0);
            c.routing = Routing::RandomLtd(LtdConfig::mslg(
                r,
                ((steps as f64 * f) as u64).max(1),
            ));
            out.push(c);
        }
    }
    out
}

fn median_std(xs: &[f64]) -> (f64, f64) {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = s[s.len() / 2];
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let std = (xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64).sqrt();
    (med, std)
}

fn main() -> dsde::Result<()> {
    let quick = quick_mode();
    let steps: u64 = if quick { 12 } else { 40 };
    let n_docs = if quick { 200 } else { 600 };
    let seeds: Vec<u64> = if quick { vec![1234, 1235] } else { vec![1234, 1235, 1236] };
    eprintln!("== Tab. 5: GPT-2-finetune-style sweep ({steps} steps/run) ==");
    // Small corpus = the "finetune" dataset (PTB stand-in).
    let env = TrainEnv::new(n_docs, 99)?;
    let max_seq = env.rt.registry.family("gpt")?.max_seq;

    // baseline at seed 1234
    let base = run_cases(&env, vec![RunConfig::baseline("gpt", steps, 3e-3)])?;
    let base_ppl = base[0].perplexity();

    // CL sweeps (seqres expected best) + rLTD sweep at seed 1234
    let mut sweep_results = Vec::new();
    let sweeps: Vec<(&str, Vec<RunConfig>)> = if quick {
        vec![
            ("CL_seqres", sweep_cl(steps, max_seq, Metric::SeqRes, 1234)[..4].to_vec()),
            ("random-LTD", sweep_ltd(steps, max_seq, 1234)[..4].to_vec()),
        ]
    } else {
        vec![
            ("CL_seqtru", sweep_cl(steps, max_seq, Metric::SeqTru, 1234)),
            ("CL_seqres", sweep_cl(steps, max_seq, Metric::SeqRes, 1234)),
            ("random-LTD", sweep_ltd(steps, max_seq, 1234)),
        ]
    };
    let mut table = Table::new(&["case", "best ppl@1234", "combos > baseline", "median±std (seeds)"]);
    table.row(vec![
        "(1)baseline".into(),
        format!("{base_ppl:.3}"),
        "N/A".into(),
        seed_stats(&env, RunConfig::baseline("gpt", steps, 3e-3), &seeds)?,
    ]);
    for (name, cases) in sweeps {
        let n_total = cases.len();
        let results = run_cases(&env, cases.clone())?;
        let mut best_idx = 0;
        let mut n_beat = 0;
        for (i, r) in results.iter().enumerate() {
            if r.perplexity() < base_ppl {
                n_beat += 1;
            }
            if r.perplexity() < results[best_idx].perplexity() {
                best_idx = i;
            }
        }
        let best_cfg = cases[best_idx].clone();
        eprintln!("{name}: best combo = {}", best_cfg.label);
        table.row(vec![
            format!("{name} (best: {})", best_cfg.label),
            format!("{:.3}", results[best_idx].perplexity()),
            format!("{n_beat} out of {n_total}"),
            seed_stats(&env, best_cfg.clone(), &seeds)?,
        ]);
        sweep_results.push((name.to_string(), results[best_idx].perplexity(), n_beat, n_total, best_cfg));
    }

    // composed: best CL + best rLTD (re-tuned T_c < T_r per §A.3)
    if sweep_results.len() >= 2 {
        let cl_best = &sweep_results[sweep_results.len() - 2].4;
        let ltd_best = &sweep_results[sweep_results.len() - 1].4;
        let mut comp = cl_best.clone();
        comp.label = "CL+rLTD".into();
        if let Routing::RandomLtd(l) = &ltd_best.routing {
            comp.routing = Routing::RandomLtd(l.clone());
        }
        if let Some(cl) = comp.curriculum.first_mut() {
            cl.total_steps = (steps as f64 * 0.1) as u64 + 1; // T_c < T_r
        }
        let r = run_cases(&env, vec![comp.clone()])?;
        table.row(vec![
            "CL+random-LTD".into(),
            format!("{:.3}", r[0].perplexity()),
            "N/A".into(),
            seed_stats(&env, comp, &seeds)?,
        ]);
    }

    println!("\nTab. 5 (reproduced)");
    table.print();
    table.save_csv("table5_gpt2_finetune")?;
    println!("\nshape checks:");
    for (name, best, n_beat, n_total, _) in &sweep_results {
        println!(
            "  [{}] {name}: best ppl {best:.3} vs baseline {base_ppl:.3}; robust {n_beat}/{n_total}",
            if *best < base_ppl { "PASS" } else { "FAIL" }
        );
    }
    Ok(())
}

fn seed_stats(env: &TrainEnv, cfg: RunConfig, seeds: &[u64]) -> dsde::Result<String> {
    let mut ppls = Vec::new();
    for &s in seeds {
        let mut c = cfg.clone();
        c.seed = s;
        c.label = format!("{}-s{}", c.label, s);
        ppls.push(env.run(c)?.perplexity());
    }
    let (med, std) = median_std(&ppls);
    Ok(format!("{med:.3}±{std:.3}"))
}
