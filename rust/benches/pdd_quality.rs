//! pdd_quality — quality-vs-tokens rows for the new sampler policies
//! (ISSUE 9 satellite).
//!
//! Three checks in one smoke bench:
//!
//! 1. **PDD pareto rows**: at each dropout endpoint, a fixed-schedule
//!    baseline vs the same run with progressive data dropout. The PDD arm
//!    must train on strictly fewer data tokens (the masked rows stop
//!    counting) at comparable final quality.
//! 2. **Loss-signal row**: the composed loss-signal-curriculum + PDD run
//!    vs the fixed baseline — same pareto shape from the self-supervised
//!    difficulty signal.
//! 3. **Drift check**: the MoE case composing the loss-signal curriculum
//!    with PDD runs twice and MUST agree bit-for-bit (`state_hash`,
//!    per-step f32 losses). Any divergence exits non-zero so the CI
//!    bench-smoke job goes red on a determinism break even before the
//!    equivalence suites run.
//!
//! `DSDE_BENCH_QUICK=1` shrinks the sweep for the CI smoke job;
//! `DSDE_BENCH_HISTORY=1` appends the report to `BENCH_HISTORY.json`.

use dsde::bench::{history_append, quick_mode, scaled, Table};
use dsde::config::json::Json;
use dsde::config::schema::{PddConfig, RunConfig};
use dsde::exp::cases::{loss_signal, pdd_quality_pairs};
use dsde::exp::relative_quality;
use dsde::train::TrainEnv;

/// The composed quick case: loss-signal curriculum + PDD on the given
/// family. Exercises both new policies (and, on `moe`, the expert grid)
/// in a single run.
fn composed_case(family: &str, steps: u64, seed: u64) -> RunConfig {
    let mut c = RunConfig::baseline(family, steps, 3e-3);
    c.label = format!("{family}+loss-signal+pdd");
    c.seed = seed;
    c.curriculum.push(loss_signal((steps as f64 * 0.4) as u64));
    c.pdd = Some(PddConfig::new(0.0, 0.3, 4, ((steps as f64 * 0.8) as u64).max(1)));
    c
}

fn main() -> dsde::Result<()> {
    let steps = scaled(60, 10);
    let docs = scaled(800, 300) as usize;
    let f_ends: Vec<f64> = if quick_mode() { vec![0.3] } else { vec![0.1, 0.3, 0.5] };
    eprintln!("== pdd_quality: {} dropout endpoints x {steps} steps ==", f_ends.len());
    let env = TrainEnv::new(docs, 7)?;

    let mut t = Table::new(&[
        "case",
        "trained data tokens",
        "dropped tokens",
        "quality % (vs fixed)",
    ]);
    let mut fewer_tokens = true;
    let mut comparable = true;
    let mut report_rows = Vec::new();
    for (f_end, base, pdd) in pdd_quality_pairs(steps, 4242, &f_ends) {
        let b = env.run(base)?;
        let p = env.run(pdd)?;
        let qb = relative_quality(b.final_eval_loss, b.final_eval_loss);
        let qp = relative_quality(b.final_eval_loss, p.final_eval_loss);
        fewer_tokens &= p.data_tokens < b.data_tokens && p.pdd_dropped_tokens > 0;
        // "comparable": within 10% relative quality of the fixed schedule.
        comparable &= qp >= qb - 10.0;
        for (name, r, q) in [(b.label.clone(), &b, qb), (p.label.clone(), &p, qp)] {
            t.row(vec![
                name,
                format!("{}", r.data_tokens),
                format!("{}", r.pdd_dropped_tokens),
                format!("{q:.1}"),
            ]);
        }
        report_rows.push(Json::obj(vec![
            ("f_end", f_end.into()),
            ("baseline_tokens", (b.data_tokens as usize).into()),
            ("pdd_tokens", (p.data_tokens as usize).into()),
            ("pdd_quality_pct", qp.into()),
        ]));
    }

    // Loss-signal pareto row: composed policies vs the fixed baseline.
    let fixed = {
        let mut c = RunConfig::baseline("gpt", steps, 3e-3);
        c.label = "gpt-fixed".into();
        c.seed = 4242;
        c
    };
    let b = env.run(fixed)?;
    let c = env.run(composed_case("gpt", steps, 4242))?;
    let qc = relative_quality(b.final_eval_loss, c.final_eval_loss);
    fewer_tokens &= c.data_tokens < b.data_tokens;
    comparable &= qc >= 90.0;
    t.row(vec![b.label.clone(), format!("{}", b.data_tokens), "0".into(), "100.0".into()]);
    t.row(vec![c.label.clone(), format!("{}", c.data_tokens), format!("{}", c.pdd_dropped_tokens), format!("{qc:.1}")]);

    println!("\npdd_quality (quality normalized to each fixed-schedule baseline):");
    t.print();
    t.save_csv("pdd_quality")?;

    // Determinism drift check on the MoE composed case: two runs of the
    // identical config must agree bit-for-bit.
    let moe_steps = steps.min(10);
    let r1 = env.run(composed_case("moe", moe_steps, 4242))?;
    let r2 = env.run(composed_case("moe", moe_steps, 4242))?;
    let drift_free = r1.state_hash == r2.state_hash
        && r1.step_losses == r2.step_losses
        && r1.final_eval_loss.to_bits() == r2.final_eval_loss.to_bits();

    history_append(
        "pdd_quality",
        &Json::obj(vec![
            ("steps", (steps as usize).into()),
            ("pairs", Json::Arr(report_rows)),
            ("loss_signal_quality_pct", qc.into()),
            ("fewer_tokens", fewer_tokens.into()),
            ("comparable_quality", comparable.into()),
            ("moe_drift_free", drift_free.into()),
        ]),
    )?;
    println!(
        "\nshape checks:\n  [{}] every policy arm trains on fewer data tokens\n  \
         [{}] quality stays comparable to the fixed schedule\n  \
         [{}] moe+loss-signal+pdd is bit-identical across reruns ({:016x})",
        if fewer_tokens { "PASS" } else { "FAIL" },
        if comparable { "PASS" } else { "FAIL" },
        if drift_free { "PASS" } else { "FAIL" },
        r1.state_hash,
    );
    if !(fewer_tokens && drift_free) {
        // Enforcing, not advisory: token accounting and bit-exact
        // determinism are the contract; quality is scale-sensitive and
        // reported but only enforced via the history log.
        std::process::exit(1);
    }
    Ok(())
}
