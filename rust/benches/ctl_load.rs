//! ctl_load — concurrent-load harness for the TCP control plane
//! (ISSUE 6 tentpole).
//!
//! Hammers a live `serve_with` front end with ≥200 concurrent submitter
//! threads: a handful submit *real* training jobs and poll them to
//! completion; the rest pump batched `SUBMIT`s of decoy jobs and `CANCEL`
//! them straight back, with periodic `METRICS` probes mixed in. Every
//! client retries explicit backpressure rejects (`queue full` /
//! `server busy`), so the bench doubles as a check that overload degrades
//! into immediate, parseable rejects rather than stalls.
//!
//! Reported: client-side p50/p99 command latency, accepted-SUBMIT
//! throughput, reject/retry counts, and the server's own `METRICS`
//! gauges. Every real job's wire-reported `state_hash` MUST equal the
//! same config executed sequentially on an identically-seeded
//! environment; any drift exits non-zero so CI goes red on a
//! concurrency-induced bit-neutrality break.
//!
//! `DSDE_BENCH_QUICK=1` shrinks the grid (but never below the 200
//! submitters the tentpole promises) for the CI smoke job.

use dsde::bench::{history_append, scaled, Table};
use dsde::config::json::Json;
use dsde::config::schema::{Bound, ClConfig, LtdConfig, Metric, Routing, RunConfig};
use dsde::exp::run_cases;
use dsde::orch::{serve_with, SchedStats, SchedulerConfig, ServeOptions};
use dsde::train::TrainEnv;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// One client command over a fresh connection (connections are cheap and
/// the server's worker pool serves one connection at a time, so holding
/// hundreds open would measure the backlog, not the command path).
fn try_rpc(addr: &str, line: &str) -> std::io::Result<Json> {
    let mut s = TcpStream::connect(addr)?;
    s.set_read_timeout(Some(Duration::from_secs(30)))?;
    s.write_all(line.as_bytes())?;
    s.write_all(b"\n")?;
    let mut reply = String::new();
    BufReader::new(s).read_line(&mut reply)?;
    if reply.trim().is_empty() {
        return Err(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "no reply"));
    }
    Json::parse(reply.trim())
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, format!("{e}")))
}

/// Retry-on-backpressure client. Explicit rejects and dropped
/// connections are counted and retried; anything else is returned with
/// its end-to-end latency recorded.
fn rpc(addr: &str, line: &str, rejects: &mut u64, lat_us: &mut Vec<u64>) -> Json {
    for _attempt in 0..100_000 {
        let t0 = Instant::now();
        match try_rpc(addr, line) {
            Ok(resp) => {
                let rejected = resp.get("ok").as_bool() == Some(false)
                    && resp
                        .get("error")
                        .as_str()
                        .map(|e| e.contains("queue full") || e.contains("server busy"))
                        .unwrap_or(false);
                if rejected {
                    *rejects += 1;
                    std::thread::sleep(Duration::from_millis(2));
                    continue;
                }
                lat_us.push(t0.elapsed().as_micros() as u64);
                return resp;
            }
            Err(_) => {
                // backlog-reject drop or transient connect failure
                *rejects += 1;
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }
    panic!("command never accepted after 100000 attempts: {line}");
}

fn composed(label: &str, steps: u64, max_seq: usize, seed: u64) -> RunConfig {
    let mut c = RunConfig::baseline("gpt", steps, 3e-3);
    c.label = label.to_string();
    c.seed = seed;
    c.curriculum.push(ClConfig::new(
        Metric::SeqTru,
        Bound::Value((max_seq / 8) as f64),
        Bound::Value(max_seq as f64),
        (steps as f64 * 0.6) as u64,
    ));
    c.routing = Routing::RandomLtd(LtdConfig::mslg(max_seq / 4, steps));
    c
}

/// Per-submitter results, merged after the load phase.
#[derive(Default)]
struct Out {
    lat_us: Vec<u64>,
    rejects: u64,
    submits_ok: u64,
    /// `(label, wire state_hash, completed_steps)` for real jobs.
    real: Option<(String, String, u64)>,
}

fn main() -> dsde::Result<()> {
    let submitters = scaled(300, 200) as usize; // tentpole floor: ≥200 even quick
    let real_jobs = scaled(8, 4) as usize;
    let batch = scaled(6, 3) as usize;
    let steps = scaled(30, 10);
    let slice = scaled(10, 3);
    let docs = scaled(400, 200) as usize;
    eprintln!(
        "== ctl_load: {submitters} submitters ({real_jobs} real x {steps} steps, \
         rest {batch}-job decoy batches) =="
    );

    let dir = std::env::temp_dir().join(format!("dsde-ctl-load-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let save_dir = dir.to_string_lossy().into_owned();

    // ---- sequential reference on an identically-seeded environment
    let ref_env = TrainEnv::new(docs, 7)?;
    let max_seq = ref_env.rt.registry.family("gpt")?.max_seq;
    let mut cases = Vec::new();
    for i in 0..real_jobs {
        let mut c = composed(&format!("real-{i}"), steps, max_seq, 1000 + i as u64);
        c.save_dir = save_dir.clone();
        cases.push(c);
    }
    let reference = run_cases(&ref_env, cases.clone())?;
    drop(ref_env);

    // ---- live server (executor thread owns its own, identically-seeded env)
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let server = std::thread::spawn(move || -> dsde::Result<SchedStats> {
        let env = TrainEnv::new(docs, 7)?;
        serve_with(
            &env,
            listener,
            ServeOptions {
                sched: SchedulerConfig {
                    max_active: 8,
                    default_slice: slice,
                    quantum: slice,
                    cleanup_done: false,
                },
                default_family: "gpt".into(),
                conn_threads: 16,
                ..ServeOptions::default()
            },
        )
    });

    // ---- load phase
    let t0 = Instant::now();
    let outs: Vec<Out> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..submitters {
            let addr = &addr;
            let save_dir = &save_dir;
            let real_cfg = cases.get(t).cloned();
            handles.push(scope.spawn(move || {
                let mut out = Out::default();
                if let Some(cfg) = real_cfg {
                    run_real(addr, &cfg, &mut out);
                } else {
                    run_decoys(addr, save_dir, t, batch, &mut out);
                }
                out
            }));
        }
        handles.into_iter().map(|h| h.join().expect("submitter thread")).collect()
    });
    let wall = t0.elapsed().as_secs_f64();

    // ---- merge client-side observations
    let mut lat: Vec<u64> = Vec::new();
    let mut rejects = 0u64;
    let mut submits_ok = 0u64;
    let mut real: Vec<(String, String, u64)> = Vec::new();
    for mut o in outs {
        lat.append(&mut o.lat_us);
        rejects += o.rejects;
        submits_ok += o.submits_ok;
        real.extend(o.real);
    }
    lat.sort_unstable();
    let q = |q: f64| -> u64 {
        if lat.is_empty() {
            return 0;
        }
        lat[((q * (lat.len() - 1) as f64).round() as usize).min(lat.len() - 1)]
    };
    let (p50, p99) = (q(0.50), q(0.99));

    // ---- server-side view, then shut down
    let (mut r, mut l) = (0u64, Vec::new());
    let metrics = rpc(&addr, r#"{"cmd":"METRICS"}"#, &mut r, &mut l);
    let drain = rpc(&addr, r#"{"cmd":"DRAIN"}"#, &mut r, &mut l);
    assert_eq!(drain.get("ok").as_bool(), Some(true), "{drain:?}");
    let stats = server.join().expect("server thread")?;

    // ---- drift check: wire-reported hashes vs the sequential reference
    let mut t = Table::new(&["job", "steps", "state hash (wire)", "reference", "drift"]);
    let mut identical = real.len() == real_jobs;
    for reference in &reference {
        let expect = format!("{:016x}", reference.state_hash);
        let (hash, done) = real
            .iter()
            .find(|(label, _, _)| *label == reference.label)
            .map(|(_, h, s)| (h.clone(), *s))
            .unwrap_or(("MISSING".into(), 0));
        let drift = hash != expect || done != steps;
        identical &= !drift;
        t.row(vec![
            reference.label.clone(),
            done.to_string(),
            hash,
            expect,
            if drift { "DRIFT".into() } else { "ok".into() },
        ]);
    }
    println!("\nreal jobs under load vs sequential reference:");
    t.print();
    t.save_csv("ctl_load")?;

    let m = |path: &str| metrics.path(path).as_u64().unwrap_or(0);
    println!(
        "\nload: {} commands in {wall:.2}s from {submitters} submitters \
         ({submits_ok} submits accepted, {:.0} submits/s, {rejects} client-side \
         retries on explicit rejects)",
        lat.len(),
        submits_ok as f64 / wall.max(1e-9),
    );
    println!("client latency: p50 {p50}us, p99 {p99}us");
    println!(
        "server gauges: {} requests, rejects queue/conns/oversize {}/{}/{}, \
         {} parse errors, server p50/p99 {}us/{}us, {} slices, {} preemptions, \
         {} completed, {} cancelled",
        m("requests"),
        m("rejects.queue"),
        m("rejects.conns"),
        m("rejects.oversize"),
        m("parse_errors"),
        m("latency_us.p50"),
        m("latency_us.p99"),
        m("sched.slices"),
        m("sched.preemptions"),
        m("sched.completed"),
        m("sched.cancelled"),
    );

    let report = Json::obj(vec![
        ("submitters", submitters.into()),
        ("real_jobs", real_jobs.into()),
        ("decoy_batch", batch.into()),
        ("commands", lat.len().into()),
        ("wall_s", wall.into()),
        ("submits_accepted", submits_ok.into()),
        ("submit_throughput_per_s", (submits_ok as f64 / wall.max(1e-9)).into()),
        ("client_reject_retries", rejects.into()),
        ("client_p50_us", p50.into()),
        ("client_p99_us", p99.into()),
        ("server_requests", m("requests").into()),
        ("server_rejects_queue", m("rejects.queue").into()),
        ("server_rejects_conns", m("rejects.conns").into()),
        ("server_p50_us", m("latency_us.p50").into()),
        ("server_p99_us", m("latency_us.p99").into()),
        ("slices", stats.slices.into()),
        ("preemptions", stats.preemptions.into()),
        ("completed", stats.completed.into()),
        ("cancelled", stats.cancelled.into()),
        ("bit_identical", identical.into()),
    ]);
    std::fs::create_dir_all("runs")?;
    std::fs::write("runs/BENCH_ctl.json", report.to_string_compact())?;
    history_append("ctl_load", &report)?;
    println!("report -> runs/BENCH_ctl.json");

    println!(
        "\nshape check:\n  [{}] >=200 concurrent submitters\n  [{}] every real job \
         served under load is bit-identical to its sequential reference",
        if submitters >= 200 { "PASS" } else { "FAIL" },
        if identical { "PASS" } else { "FAIL" }
    );
    let _ = std::fs::remove_dir_all(&dir);
    if submitters < 200 || !identical {
        // Enforcing, not advisory: concurrency must not buy drift.
        std::process::exit(1);
    }
    Ok(())
}

/// Submit one real job and poll STATUS until the server reports it done,
/// capturing the wire-reported state hash.
fn run_real(addr: &str, cfg: &RunConfig, out: &mut Out) {
    let submit = Json::obj(vec![
        ("cmd", "SUBMIT".into()),
        ("config", cfg.to_json()),
        ("priority", 3usize.into()), // outrank the decoy flood
    ])
    .to_string_compact();
    let resp = rpc(addr, &submit, &mut out.rejects, &mut out.lat_us);
    assert_eq!(resp.get("ok").as_bool(), Some(true), "real SUBMIT: {resp:?}");
    out.submits_ok += 1;
    let id = resp.get("job").as_u64().expect("job id");

    let status = Json::obj(vec![("cmd", "STATUS".into()), ("job", id.into())])
        .to_string_compact();
    let deadline = Instant::now() + Duration::from_secs(600);
    loop {
        let st = rpc(addr, &status, &mut out.rejects, &mut out.lat_us);
        let state = st.path("job.state").as_str().unwrap_or("?").to_string();
        if state == "done" {
            out.real = Some((
                cfg.label.clone(),
                st.path("job.state_hash").as_str().unwrap_or("NO-HASH").to_string(),
                st.path("job.completed_steps").as_u64().unwrap_or(0),
            ));
            return;
        }
        assert_ne!(state, "failed", "{st:?}");
        assert!(Instant::now() < deadline, "job {id} stuck in state {state}");
        std::thread::sleep(Duration::from_millis(25));
    }
}

/// Pump one batched SUBMIT of tiny decoy jobs, then CANCEL each straight
/// back (a cancel that loses the race to completion is fine — the job is
/// terminal either way). Every 8th submitter probes METRICS, which must
/// answer connection-side even while the command queue is rejecting.
fn run_decoys(addr: &str, save_dir: &str, t: usize, batch: usize, out: &mut Out) {
    let entries: Vec<Json> = (0..batch)
        .map(|m| {
            let mut c = RunConfig::baseline("gpt", 4, 3e-3);
            c.label = format!("decoy-{t}-{m}");
            c.seed = (7000 + t * batch + m) as u64;
            c.save_dir = save_dir.to_string();
            Json::obj(vec![("config", c.to_json()), ("priority", 1usize.into())])
        })
        .collect();
    let submit = Json::obj(vec![("cmd", "SUBMIT".into()), ("jobs", Json::Arr(entries))])
        .to_string_compact();
    let resp = rpc(addr, &submit, &mut out.rejects, &mut out.lat_us);
    assert_eq!(resp.get("ok").as_bool(), Some(true), "batch SUBMIT: {resp:?}");
    let verdicts = match resp.get("jobs") {
        Json::Arr(a) => a.clone(),
        other => panic!("batch reply missing per-job verdicts: {other:?}"),
    };
    assert_eq!(verdicts.len(), batch, "one verdict per submitted entry");
    for v in &verdicts {
        assert_eq!(v.get("ok").as_bool(), Some(true), "decoy rejected: {v:?}");
        out.submits_ok += 1;
        let id = v.get("job").as_u64().expect("decoy job id");
        let cancel = Json::obj(vec![("cmd", "CANCEL".into()), ("job", id.into())])
            .to_string_compact();
        let _ = rpc(addr, &cancel, &mut out.rejects, &mut out.lat_us);
    }
    if t % 8 == 0 {
        let m = rpc(addr, r#"{"cmd":"METRICS"}"#, &mut out.rejects, &mut out.lat_us);
        assert_eq!(m.get("ok").as_bool(), Some(true), "{m:?}");
        assert!(m.get("queue_cap").as_u64().unwrap_or(0) > 0, "{m:?}");
    }
}
