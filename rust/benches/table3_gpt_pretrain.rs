//! Tab. 3 reproduction — GPT pretraining grid (cases 1–15) plus the GPT-3
//! MoE cases (16–17).
//!
//! Paper shape to reproduce (not absolute numbers — DESIGN.md §Substitutions):
//!  * all CL metrics ≥ baseline quality at 100% data; composed CL_seqtru_voc best;
//!  * CL / random-LTD at 67% data ≈ baseline at 100%;
//!  * composed at 50% data ≈ baseline at 100% (the 2x saving headline);
//!  * MoE: composed beats MoE baseline.
//!
//! `DSDE_BENCH_QUICK=1` shrinks the grid for smoke runs.

use dsde::bench::{scaled, Table};
use dsde::exp::cases::{table3_gpt, table3_moe};
use dsde::exp::{run_cases, table_headers, table_row};
use dsde::sim::CostModel;
use dsde::train::TrainEnv;

fn main() -> dsde::Result<()> {
    let full_steps = scaled(100, 16);
    let moe_steps = scaled(60, 8);
    let n_docs = scaled(800, 300) as usize;
    eprintln!("== Tab. 3: GPT pretraining grid (full budget {full_steps} steps) ==");
    let env = TrainEnv::new(n_docs, 7)?;
    let fam = env.rt.registry.family("gpt")?.clone();

    let results = run_cases(&env, table3_gpt(full_steps, fam.max_seq, 1234))?;
    let baseline = &results[0];
    let cost = CostModel::new(baseline.compute_tokens, baseline.wall_secs);

    let mut table = Table::new(&table_headers());
    for r in &results {
        table.row(table_row(r, &cost, baseline.final_eval_loss));
    }

    // MoE section (paper cases 16/17) — separate quality scale.
    let moe_results = run_cases(&env, table3_moe(moe_steps, fam.max_seq, 1234))?;
    let moe_base_loss = moe_results[0].final_eval_loss;
    let moe_cost = CostModel::new(moe_results[0].compute_tokens, moe_results[0].wall_secs);
    for r in &moe_results {
        table.row(table_row(r, &moe_cost, moe_base_loss));
    }

    println!("\nTab. 3 (reproduced at tiny scale; quality = inverse-loss % of baseline)");
    table.print();
    let csv = table.save_csv("table3_gpt_pretrain")?;
    eprintln!("csv -> {}", csv.display());

    // ---- shape checks ----
    let loss = |i: usize| results[i].final_eval_loss;
    let checks: Vec<(String, bool)> = vec![
        ("composed(8) beats baseline(1) at 100% data".into(), loss(7) < loss(0)),
        ("CL_seqtru_voc(5) beats baseline(1)".into(), loss(4) < loss(0)),
        ("baseline@50%(12) worse than baseline@100%(1)".into(), loss(11) > loss(0)),
        ("composed@50%(15) recovers vs baseline@50%(12)".into(), loss(14) < loss(11)),
        (
            "MoE composed(17) beats MoE baseline(16)".into(),
            moe_results[1].final_eval_loss < moe_results[0].final_eval_loss,
        ),
    ];
    println!("\nshape checks:");
    for (name, ok) in &checks {
        println!("  [{}] {name}", if *ok { "PASS" } else { "FAIL" });
    }
    Ok(())
}
