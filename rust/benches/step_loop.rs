//! step_loop — raw-speed pass over the step loop and checkpoint path
//! (ISSUE 8 tentpole).
//!
//! Three measurements, every one gated by bit-identity:
//!
//! 1. **steps/sec** on a tiny-step composed GPT config — the sequential
//!    (sync loader, fused) reference vs the interned-dispatch + pooled
//!    zero-copy pipeline path. The two runs MUST agree bit-for-bit
//!    (`state_hash`, per-step f32 losses, dispatch histogram); any drift
//!    exits non-zero so the CI bench-smoke job goes red.
//! 2. **checkpoint encode + write MB/s** on a synthetic multi-MB
//!    snapshot: the parallel section-filled encode must be byte-stable
//!    across repeats and decode back to the identical checkpoint.
//! 3. **per-slice preemption overhead**: full-image save vs DELTA-record
//!    save (few tensors changed), wall time and bytes — the cost a
//!    preempted slice actually pays at its boundary.
//!
//! Results land in `BENCH_HISTORY.json` under `step_loop` when
//! `DSDE_BENCH_HISTORY=1`. `DSDE_BENCH_QUICK=1` shrinks everything for
//! the CI smoke job.

use dsde::bench::{history_append, scaled, Table};
use dsde::config::json::Json;
use dsde::config::schema::*;
use dsde::train::checkpoint::{image_checksum, Checkpoint, DeltaBase, TensorSnap};
use dsde::train::{CurvePoint, Engine, TrainEnv};
use std::time::Instant;

fn tiny_case(steps: u64, pipeline_on: bool) -> RunConfig {
    let mut c = RunConfig::baseline("gpt", steps, 3e-3);
    c.label = if pipeline_on { "pipelined" } else { "sequential" }.into();
    c.seed = 4242;
    c.eval_every = steps; // keep the loop hot: evaluate only at the end
    c.curriculum = vec![ClConfig::new(
        Metric::SeqTru,
        Bound::Value(8.0),
        Bound::Value(64.0),
        (steps as f64 * 0.6) as u64,
    )];
    c.routing = Routing::RandomLtd(LtdConfig::mslg(16, steps));
    c.pipeline = if pipeline_on {
        PipelineConfig { prefetch_depth: 3, n_loader_workers: 4 }
    } else {
        PipelineConfig::disabled()
    };
    c
}

/// A synthetic snapshot big enough to cross the parallel-encode
/// threshold: `n_tensors` square-ish f32 tensors of `elems` elements.
fn synthetic_ckpt(n_tensors: usize, elems: usize) -> Checkpoint {
    let state = (0..n_tensors)
        .map(|t| TensorSnap {
            dims: vec![elems as i64],
            data: (0..elems).map(|i| ((t * 31 + i) % 997) as f32 * 0.125).collect(),
        })
        .collect();
    Checkpoint {
        family: "gpt".into(),
        step: 500,
        total_steps: 1000,
        n_replicas: 0,
        engine: Engine::Fused,
        schedule_fp: 0x5eed_cafe_f00d_0001,
        state,
        accountant: [500, 1 << 20, 1 << 18, 4],
        dropper_rng: (0x9e37_79b9_7f4a_7c15, 0xda94_2042_e4dd_58b5),
        importance: None,
        step_losses: (0..500).map(|i| 5.0 - i as f32 * 0.005).collect(),
        curve: (0..10u64)
            .map(|i| CurvePoint {
                step: i * 50,
                compute_tokens: (i * 50 * 4096) as f64,
                eval_loss: 5.0 - i as f64 * 0.2,
            })
            .collect(),
    }
}

fn main() -> dsde::Result<()> {
    let steps = scaled(160, 12);
    let docs = scaled(400, 200) as usize;
    eprintln!("== step_loop: steps/sec, encode MB/s, preemption overhead ==");
    let env = TrainEnv::new(docs, 7)?;

    // ---- 1. step-loop throughput, sequential vs pipelined ----------------
    let seq = env.run(tiny_case(steps, false))?;
    let piped = env.run(tiny_case(steps, true))?;
    let seq_sps = steps as f64 / seq.wall_secs.max(1e-9);
    let piped_sps = steps as f64 / piped.wall_secs.max(1e-9);
    let loop_ok = seq.state_hash == piped.state_hash
        && seq.step_losses == piped.step_losses
        && seq.dispatch == piped.dispatch;

    let mut t = Table::new(&["path", "steps", "wall s", "steps/s"]);
    t.row(vec![
        "sequential".into(),
        steps.to_string(),
        format!("{:.3}", seq.wall_secs),
        format!("{seq_sps:.1}"),
    ]);
    t.row(vec![
        "pipelined".into(),
        steps.to_string(),
        format!("{:.3}", piped.wall_secs),
        format!("{piped_sps:.1}"),
    ]);
    println!("\nstep-loop throughput (composed GPT, {steps} tiny steps):");
    t.print();

    // ---- 2. parallel checkpoint encode + write MB/s ----------------------
    let (n_tensors, elems) = if dsde::bench::quick_mode() { (8, 1 << 16) } else { (24, 1 << 18) };
    let ck = synthetic_ckpt(n_tensors, elems);
    let reps = scaled(20, 3) as usize;
    let first = ck.encode();
    let mb = first.len() as f64 / (1024.0 * 1024.0);
    let t0 = Instant::now();
    let mut encode_ok = true;
    for _ in 0..reps {
        encode_ok &= ck.encode() == first;
    }
    let encode_s = t0.elapsed().as_secs_f64() / reps as f64;
    // Roundtrip gate: the parallel fill must decode to the same snapshot.
    encode_ok &= Checkpoint::decode(&first).map(|d| d == ck).unwrap_or(false);

    let dir = std::env::temp_dir().join(format!("dsde-step-loop-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let full_path = dir.join(format!("step{:06}.ckpt", ck.step));
    let t0 = Instant::now();
    ck.save(&full_path)?;
    let full_save_s = t0.elapsed().as_secs_f64();

    // ---- 3. preemption overhead: full vs delta save ----------------------
    // A boundary where only a couple of tensors moved since the base —
    // the delta writes just those plus the bookkeeping sections.
    let base = DeltaBase {
        step: ck.step,
        file_fnv: image_checksum(&std::fs::read(&full_path)?)?,
        tensor_fnvs: ck.tensor_fnvs(),
    };
    let mut next = ck.clone();
    next.step += 10;
    next.step_losses.extend((0..10).map(|i| 2.5 - i as f32 * 0.001));
    next.state[0].data[0] += 1.0;
    next.state[n_tensors / 2].data[7] += 1.0;
    let delta_path = dir.join(format!("step{:06}.ckpt", next.step));
    let t0 = Instant::now();
    let (delta_bytes, n_changed) = next.encode_delta(&base)?;
    dsde::train::checkpoint::write_snapshot(&delta_path, &delta_bytes)?;
    let delta_save_s = t0.elapsed().as_secs_f64();
    // Chain gate: full+delta restore must equal the in-memory snapshot.
    let delta_ok =
        n_changed == 2 && Checkpoint::load_chain(&delta_path).map(|c| c == next).unwrap_or(false);

    let full_bytes = first.len();
    let mut t = Table::new(&["publish", "bytes", "wall ms", "MB/s"]);
    for (name, bytes, secs) in [
        ("encode (mem)", full_bytes, encode_s),
        ("full save", full_bytes, full_save_s),
        ("delta save", delta_bytes.len(), delta_save_s),
    ] {
        t.row(vec![
            name.into(),
            bytes.to_string(),
            format!("{:.2}", secs * 1e3),
            format!("{:.1}", bytes as f64 / (1024.0 * 1024.0) / secs.max(1e-9)),
        ]);
    }
    println!("\ncheckpoint path ({n_tensors} tensors × {elems} f32, {mb:.1} MB image):");
    t.print();
    t.save_csv("step_loop")?;
    println!(
        "delta record: {n_changed} changed tensors, {:.1}% of the full image",
        100.0 * delta_bytes.len() as f64 / full_bytes as f64
    );

    history_append(
        "step_loop",
        &Json::obj(vec![
            ("steps", (steps as usize).into()),
            ("seq_steps_per_s", seq_sps.into()),
            ("piped_steps_per_s", piped_sps.into()),
            ("encode_mb_per_s", (mb / encode_s.max(1e-9)).into()),
            ("full_save_s", full_save_s.into()),
            ("delta_save_s", delta_save_s.into()),
            ("full_bytes", full_bytes.into()),
            ("delta_bytes", delta_bytes.len().into()),
            ("bit_identical", (loop_ok && encode_ok && delta_ok).into()),
        ]),
    )?;

    println!(
        "\nshape check:\n  [{}] pipelined step loop bit-identical to sequential reference\n  \
         [{}] parallel encode byte-stable and decode-roundtrips\n  \
         [{}] full+delta chain restores the exact snapshot",
        if loop_ok { "PASS" } else { "FAIL" },
        if encode_ok { "PASS" } else { "FAIL" },
        if delta_ok { "PASS" } else { "FAIL" }
    );
    let _ = std::fs::remove_dir_all(&dir);
    if !(loop_ok && encode_ok && delta_ok) {
        // Enforcing, not advisory: every speed win is gated on identity.
        std::process::exit(1);
    }
    Ok(())
}
