//! checkpoint_smoke — save→resume→bit-identity smoke (ISSUE 4 satellite).
//!
//! Runs the composed GPT case (CL seqtru+voc + random-LTD) three ways —
//! uninterrupted, with periodic saving, and resumed from the mid-run
//! snapshot — and reports snapshot size, save overhead and resume
//! latency. The finished runs MUST agree bit-for-bit (`state_hash`,
//! per-step f32 losses, final eval); any divergence exits non-zero, so
//! the CI bench-smoke job goes red on a durability break even before
//! `tests/checkpoint_resume.rs` runs.
//!
//! `DSDE_BENCH_QUICK=1` shrinks the run for the CI smoke job.

use dsde::bench::{history_append, scaled, Table};
use dsde::config::json::Json;
use dsde::exp::cases::dp_scaling_cases;
use dsde::train::TrainEnv;

fn main() -> dsde::Result<()> {
    let steps = scaled(60, 10);
    let save_at = (steps / 2).max(1);
    let docs = scaled(800, 300) as usize;
    eprintln!("== checkpoint_smoke: save at step {save_at} of {steps}, resume, compare ==");
    let env = TrainEnv::new(docs, 7)?;
    let fam = env.rt.registry.family("gpt")?.clone();

    let mut base = dp_scaling_cases(steps, fam.max_seq, 1234, &[1])[0].clone();
    base.n_replicas = 0;
    base.label = "composed".into();

    let dir = std::env::temp_dir().join(format!("dsde-ckpt-smoke-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let reference = env.run(base.clone())?;

    let mut saving = base.clone();
    saving.label = "composed+save".into();
    saving.save_every = save_at;
    saving.save_dir = dir.to_string_lossy().into_owned();
    let t0 = std::time::Instant::now();
    let saved = env.run(saving)?;
    let save_wall = t0.elapsed().as_secs_f64();
    let snapshot = dir.join(format!("step{save_at:06}.ckpt"));
    let snap_bytes = std::fs::metadata(&snapshot).map(|m| m.len()).unwrap_or(0);

    let mut resuming = base.clone();
    resuming.label = "composed+resume".into();
    resuming.resume = Some(snapshot.to_string_lossy().into_owned());
    let resumed = env.run(resuming)?;

    let mut t = Table::new(&["run", "wall s", "eval loss", "state hash"]);
    for (name, r) in [("uninterrupted", &reference), ("saving", &saved), ("resumed", &resumed)] {
        t.row(vec![
            name.into(),
            format!("{:.2}", r.wall_secs),
            format!("{:.4}", r.final_eval_loss),
            format!("{:016x}", r.state_hash),
        ]);
    }
    println!("\ncheckpoint save→resume (composed GPT case, {steps} steps):");
    t.print();
    t.save_csv("checkpoint_smoke")?;
    println!(
        "snapshot: {} bytes at step {save_at}; saving-run overhead {:+.1}% wall; \
         resumed segment ran {} steps",
        snap_bytes,
        100.0 * (save_wall - reference.wall_secs) / reference.wall_secs.max(1e-9),
        steps - save_at,
    );

    let identical = |r: &dsde::train::RunResult| {
        r.state_hash == reference.state_hash
            && r.step_losses == reference.step_losses
            && r.final_eval_loss.to_bits() == reference.final_eval_loss.to_bits()
    };
    let save_ok = identical(&saved);
    let resume_ok = identical(&resumed) && resumed.resumed_at == save_at;
    history_append(
        "checkpoint_smoke",
        &Json::obj(vec![
            ("steps", (steps as usize).into()),
            ("save_at", (save_at as usize).into()),
            ("snapshot_bytes", (snap_bytes as usize).into()),
            ("save_overhead_s", (save_wall - reference.wall_secs).into()),
            ("bit_identical", (save_ok && resume_ok).into()),
        ]),
    )?;
    println!(
        "\nshape check:\n  [{}] saving perturbs nothing (bit-identical to uninterrupted)\n  \
         [{}] resume at step {save_at} is bit-identical end-to-end",
        if save_ok { "PASS" } else { "FAIL" },
        if resume_ok { "PASS" } else { "FAIL" }
    );
    let _ = std::fs::remove_dir_all(&dir);
    if !(save_ok && resume_ok) {
        // Enforcing, not advisory: bit-exact durability is the contract.
        std::process::exit(1);
    }
    Ok(())
}
