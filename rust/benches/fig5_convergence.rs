//! Fig. 5 reproduction — validation-perplexity convergence curves for
//! baseline vs composed (CL_seqtru_voc + random-LTD) at 100% and 50% data,
//! plus the §3.3 token-based-vs-step-based LR decay ablation.
//!
//! Paper shape: the composed run converges *slower early* (easy data +
//! aggressive dropping) but *faster late*, ending at a better (100% data)
//! or equal (50% data) final validation perplexity; and token-based LR
//! decay beats step-based for the data-efficient runs.

use dsde::bench::{scaled, Table};
use dsde::config::schema::*;
use dsde::exp::cases::{peak_lr_for_fraction, table3_gpt};
use dsde::exp::run_cases;
use dsde::train::TrainEnv;

fn main() -> dsde::Result<()> {
    let full_steps = scaled(120, 24);
    let n_docs = scaled(800, 300) as usize;
    eprintln!("== Fig. 5: convergence curves (full={full_steps} steps) ==");
    let env = TrainEnv::new(n_docs, 7)?;
    let fam = env.rt.registry.family("gpt")?.clone();

    // Reuse the Tab. 3 grid definitions for exact case parity.
    let grid = table3_gpt(full_steps, fam.max_seq, 1234);
    let mut cases = vec![
        grid[0].clone(),  // (1) baseline 100%
        grid[7].clone(),  // (8) composed 100%
        grid[11].clone(), // (12) baseline 50%
        grid[14].clone(), // (15) composed 50%
    ];
    let eval_every = (full_steps / 10).max(1);
    for c in cases.iter_mut() {
        c.eval_every = eval_every;
    }

    // LR-basis ablation: composed 100% with step-based decay.
    let mut step_lr = grid[7].clone();
    step_lr.label = "(8b)composed-stepLR".into();
    step_lr.lr.basis = LrBasis::Steps;
    step_lr.lr.decay_total = step_lr.total_steps as f64;
    step_lr.eval_every = eval_every;
    cases.push(step_lr);

    let results = run_cases(&env, cases)?;

    // Emit curves as CSV (step, compute_tokens, eval_loss per case).
    let mut table = Table::new(&["case", "step", "compute_tokens", "eval_loss", "ppl"]);
    for r in &results {
        for p in &r.curve {
            table.row(vec![
                r.label.clone(),
                p.step.to_string(),
                format!("{:.0}", p.compute_tokens),
                format!("{:.4}", p.eval_loss),
                format!("{:.2}", p.eval_loss.exp()),
            ]);
        }
    }
    let csv = table.save_csv("fig5_convergence")?;
    println!("curves -> {}", csv.display());

    let base100 = &results[0];
    let comp100 = &results[1];
    let base50 = &results[2];
    let comp50 = &results[3];
    let comp_steplr = &results[4];
    println!("\nfinal eval loss:");
    for r in &results {
        println!("  {:<24} {:.4} (ppl {:.2})", r.label, r.final_eval_loss, r.perplexity());
    }

    // early-slow / late-fast crossover: compare at ~1/4 into training vs end
    let early = |r: &dsde::train::RunResult| r.curve.first().map(|p| p.eval_loss).unwrap_or(0.0);
    println!("\nshape checks:");
    let checks = vec![
        (
            "composed@100% slower early (higher first-eval loss)".to_string(),
            early(comp100) >= early(base100) - 0.05,
        ),
        (
            "composed@100% better at the end".to_string(),
            comp100.final_eval_loss < base100.final_eval_loss,
        ),
        (
            "composed@50% ≈ baseline@100% (within 2%)".to_string(),
            comp50.final_eval_loss < base100.final_eval_loss * 1.02,
        ),
        (
            "baseline@50% worse than baseline@100%".to_string(),
            base50.final_eval_loss > base100.final_eval_loss,
        ),
        (
            "token-based LR ≥ step-based LR for composed run".to_string(),
            comp100.final_eval_loss <= comp_steplr.final_eval_loss + 1e-6,
        ),
    ];
    for (name, ok) in checks {
        println!("  [{}] {name}", if ok { "PASS" } else { "FAIL" });
    }
    let _ = peak_lr_for_fraction(1.0); // (silence unused import on quick paths)
    Ok(())
}
