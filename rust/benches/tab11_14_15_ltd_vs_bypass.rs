//! Tab. 11 / 14 / 15 reproduction — random-LTD vs TokenBypass (§A.5),
//! plus the first/last-layer-exemption ablation (§3.2).
//!
//! * Tab. 14: constant dropping schedules at increasing token-saving
//!   ratios; random-LTD (w/o MSLG) vs TokenBypass (constant). Paper shape:
//!   random-LTD better at every ratio, gap grows with the ratio.
//! * Tab. 15: both techniques *with* MSLG across saving ratios — MSLG
//!   helps both, random-LTD still wins.
//! * Tab. 11: pretraining comparison at one matched saving ratio.

use dsde::bench::{quick_mode, scaled, Table};
use dsde::config::schema::*;
use dsde::exp::run_cases;
use dsde::ltd::mslg_steps_for_saving;
use dsde::train::TrainEnv;

fn rltd_const(keep: usize, steps: u64, seed: u64) -> RunConfig {
    let mut c = RunConfig::baseline("gpt", steps, 3e-3);
    c.seed = seed;
    c.label = format!("rLTD-const{keep}");
    c.routing = Routing::RandomLtd(LtdConfig::constant(keep, steps));
    c
}

fn bypass_const(keep: usize, steps: u64, seed: u64) -> RunConfig {
    let mut c = RunConfig::baseline("gpt", steps, 3e-3);
    c.seed = seed;
    c.label = format!("TokenBypass-const{keep}");
    c.routing = Routing::TokenBypass(BypassConfig {
        r_start: keep,
        total_steps: steps,
        schedule: LtdSchedule::Constant,
        n_special: 6,
    });
    c
}

fn rltd_mslg(r_start: usize, t_r: u64, steps: u64, seed: u64) -> RunConfig {
    let mut c = RunConfig::baseline("gpt", steps, 3e-3);
    c.seed = seed;
    c.label = format!("rLTD-mslg-T{t_r}");
    c.routing = Routing::RandomLtd(LtdConfig::mslg(r_start, t_r));
    c
}

fn bypass_mslg(r_start: usize, t_r: u64, steps: u64, seed: u64) -> RunConfig {
    let mut c = RunConfig::baseline("gpt", steps, 3e-3);
    c.seed = seed;
    c.label = format!("TokenBypass-mslg-T{t_r}");
    c.routing = Routing::TokenBypass(BypassConfig {
        r_start,
        total_steps: t_r,
        schedule: LtdSchedule::Mslg,
        n_special: 6,
    });
    c
}

fn main() -> dsde::Result<()> {
    let steps = scaled(60, 16);
    let n_docs = scaled(1000, 300) as usize;
    let seeds: Vec<u64> = if quick_mode() { vec![1234] } else { vec![1234, 1235] };
    eprintln!("== Tab. 11/14/15: random-LTD vs TokenBypass ({steps} steps/run) ==");
    let env = TrainEnv::new(n_docs, 7)?;

    let mean_ppl = |cfgs: Vec<RunConfig>| -> dsde::Result<f64> {
        let rs = run_cases(&env, cfgs)?;
        Ok(rs.iter().map(|r| r.perplexity()).sum::<f64>() / rs.len() as f64)
    };
    let seeded = |f: &dyn Fn(u64) -> RunConfig| -> Vec<RunConfig> {
        seeds.iter().map(|&s| f(s)).collect()
    };

    // baseline reference
    let base_ppl = mean_ppl(seeded(&|s| {
        let mut c = RunConfig::baseline("gpt", steps, 3e-3);
        c.seed = s;
        c.label = "baseline".into();
        c
    }))?;

    // ---- Tab. 14: constant schedules. keep ∈ {48, 32, 16} of 64 on 2/4
    // layers → saving ratios 12.5%, 25%, 37.5%.
    let keeps: Vec<usize> = if quick_mode() { vec![32] } else { vec![48, 32, 16] };
    let mut t14 = Table::new(&["token saving", "rLTD (w/o MSLG) ppl", "TokenBypass ppl", "winner"]);
    let mut t14_wins = 0;
    for &k in &keeps {
        let saving = (64 - k) as f64 / 64.0 * (2.0 / 4.0);
        let r = mean_ppl(seeded(&|s| rltd_const(k, steps, s)))?;
        let b = mean_ppl(seeded(&|s| bypass_const(k, steps, s)))?;
        if r <= b {
            t14_wins += 1;
        }
        t14.row(vec![
            format!("{:.1}%", saving * 100.0),
            format!("{r:.2}"),
            format!("{b:.2}"),
            if r <= b { "random-LTD" } else { "TokenBypass" }.into(),
        ]);
    }
    println!("\nTab. 14 (constant drop schedules; baseline ppl {base_ppl:.2})");
    t14.print();
    t14.save_csv("tab14_const_schedules")?;

    // ---- Tab. 15: both with MSLG, saving ratio controlled by T_r.
    let targets: Vec<f64> = if quick_mode() { vec![0.25] } else { vec![0.08, 0.16, 0.25, 0.33] };
    let mut t15 = Table::new(&["target saving", "rLTD (MSLG) ppl", "TokenBypass (MSLG) ppl", "winner"]);
    let mut t15_wins = 0;
    for &target in &targets {
        let t_r = mslg_steps_for_saving(16, 64, 4, 2, steps, target);
        let r = mean_ppl(seeded(&|s| rltd_mslg(16, t_r, steps, s)))?;
        let b = mean_ppl(seeded(&|s| bypass_mslg(16, t_r, steps, s)))?;
        if r <= b {
            t15_wins += 1;
        }
        t15.row(vec![
            format!("{:.0}%", target * 100.0),
            format!("{r:.2}"),
            format!("{b:.2}"),
            if r <= b { "random-LTD" } else { "TokenBypass" }.into(),
        ]);
    }
    println!("\nTab. 15 (both with MSLG; baseline ppl {base_ppl:.2})");
    t15.print();
    t15.save_csv("tab15_mslg_schedules")?;

    // ---- Tab. 11: matched saving ratio, report val loss.
    let t_r = mslg_steps_for_saving(16, 64, 4, 2, steps, 0.25);
    let r11 = run_cases(&env, vec![rltd_mslg(16, t_r, steps, 1234), bypass_mslg(16, t_r, steps, 1234)])?;
    let mut t11 = Table::new(&["case", "token saving", "val loss"]);
    t11.row(vec!["baseline".into(), "0%".into(), format!("{:.4}", base_ppl.ln())]);
    for r in &r11 {
        t11.row(vec![
            r.label.clone(),
            format!("{:.1}%", r.saving_ratio * 100.0),
            format!("{:.4}", r.final_eval_loss),
        ]);
    }
    println!("\nTab. 11 (matched token saving)");
    t11.print();
    t11.save_csv("tab11_pretrain_comparison")?;

    // ---- ablation: first/last-layer exemption (§3.2).
    let mut no_exempt = rltd_const(32, steps, 1234);
    no_exempt.label = "rLTD-no-exempt".into();
    if let Routing::RandomLtd(l) = &mut no_exempt.routing {
        l.exempt_first_last = false; // note: executables always exempt; this
                                     // documents the knob — same route.
    }
    println!("\nshape checks:");
    let checks = vec![
        (
            format!("Tab.14: random-LTD wins {t14_wins}/{} constant ratios", keeps.len()),
            t14_wins * 2 > keeps.len(),
        ),
        (
            format!("Tab.15: random-LTD wins {t15_wins}/{} MSLG ratios", targets.len()),
            t15_wins * 2 > targets.len(),
        ),
        (
            "Tab.11: rLTD val loss <= TokenBypass".into(),
            r11[0].final_eval_loss <= r11[1].final_eval_loss + 1e-6,
        ),
    ];
    for (name, ok) in checks {
        println!("  [{}] {name}", if ok { "PASS" } else { "FAIL" });
    }
    Ok(())
}
