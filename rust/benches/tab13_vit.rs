//! Tab. 13 reproduction — ViT finetuning with random-LTD.
//!
//! Paper shape: random-LTD with MSLG to 80% of training gives a 1.3–1.4x
//! data saving while maintaining (or slightly improving) top-1 accuracy.

use dsde::bench::{quick_mode, scaled, Table};
use dsde::config::presets;
use dsde::config::schema::RunConfig;
use dsde::exp::run_cases;
use dsde::train::TrainEnv;

fn main() -> dsde::Result<()> {
    let steps = scaled(80, 16);
    let seeds: Vec<u64> = if quick_mode() { vec![1234] } else { vec![1234, 1235] };
    eprintln!("== Tab. 13: ViT finetuning with random-LTD ({steps} steps/run) ==");
    let env = TrainEnv::new(200, 7)?;

    let mut rows: Vec<(String, Vec<f64>, Vec<f64>, f64)> = Vec::new();
    for (label, make) in [
        ("baseline", Box::new(|s: u64| {
            let mut c = RunConfig::baseline("vit", steps, 3e-3);
            c.seed = s;
            c.label = format!("vit-baseline-s{s}");
            c
        }) as Box<dyn Fn(u64) -> RunConfig>),
        ("random-LTD", Box::new(|s: u64| {
            let mut c = presets::vit_finetune(steps, 3e-3);
            c.seed = s;
            c.label = format!("vit-rltd-s{s}");
            c
        })),
    ] {
        let cfgs: Vec<RunConfig> = seeds.iter().map(|&s| make(s)).collect();
        let rs = run_cases(&env, cfgs)?;
        let accs: Vec<f64> = rs.iter().filter_map(|r| r.final_accuracy).collect();
        let losses: Vec<f64> = rs.iter().map(|r| r.final_eval_loss).collect();
        let saving = rs[0].saving_ratio;
        rows.push((label.to_string(), accs, losses, saving));
    }

    let stats = |xs: &[f64]| {
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let std =
            (xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64).sqrt();
        (mean, std)
    };
    let mut table = Table::new(&["case", "compute saving", "top-1 acc", "eval loss"]);
    for (label, accs, losses, saving) in &rows {
        let (am, asd) = stats(accs);
        let (lm, _) = stats(losses);
        table.row(vec![
            label.clone(),
            format!("{:.1}% ({:.2}x)", saving * 100.0, 1.0 / (1.0 - saving).max(1e-9)),
            format!("{:.1}±{:.1}%", am * 100.0, asd * 100.0),
            format!("{lm:.4}"),
        ]);
    }
    println!("\nTab. 13 (reproduced; synthetic clustered-patch images)");
    table.print();
    table.save_csv("tab13_vit")?;

    let (base_acc, _) = stats(&rows[0].1);
    let (ltd_acc, _) = stats(&rows[1].1);
    println!("\nshape checks:");
    let checks = vec![
        (
            format!("rLTD saves compute ({:.1}%)", rows[1].3 * 100.0),
            rows[1].3 > 0.05,
        ),
        (
            format!(
                "accuracy maintained (rLTD {:.1}% vs baseline {:.1}%, tolerance 5pt)",
                ltd_acc * 100.0,
                base_acc * 100.0
            ),
            ltd_acc >= base_acc - 0.05,
        ),
    ];
    for (name, ok) in checks {
        println!("  [{}] {name}", if ok { "PASS" } else { "FAIL" });
    }
    Ok(())
}
