//! sched_throughput — multi-tenant scheduler overhead + bit-identity
//! smoke (ISSUE 5 satellite).
//!
//! Runs a mixed N-job grid (gpt composed / gpt baseline / bert composed /
//! vit ltd) twice on one shared environment: sequentially via
//! `exp::run_cases`, then through the time-slicing scheduler (preemption
//! = checkpoint-save + requeue every slice). Reports makespan for both
//! paths, the per-slice preemption overhead, and the shared JIT-cache hit
//! rate across tenants, then emits `runs/BENCH_sched.json`. Every
//! time-sliced job's `state_hash` MUST equal its uninterrupted reference;
//! any drift exits non-zero, so the CI bench-smoke job goes red on a
//! scheduler bit-neutrality break even before `tests/scheduler.rs` runs.
//!
//! `DSDE_BENCH_QUICK=1` shrinks the run for the CI smoke job.

use dsde::bench::{history_append, scaled, Table};
use dsde::config::json::Json;
use dsde::config::schema::{Bound, ClConfig, LtdConfig, Metric, Routing, RunConfig};
use dsde::exp::run_cases;
use dsde::orch::{JobSpec, JobState, Scheduler, SchedulerConfig};
use dsde::train::TrainEnv;

fn composed(family: &str, label: &str, steps: u64, max_seq: usize, r_s: usize) -> RunConfig {
    let mut c = RunConfig::baseline(family, steps, 3e-3);
    c.label = label.to_string();
    c.seed = 1234;
    c.curriculum.push(ClConfig::new(
        Metric::SeqTru,
        Bound::Value((max_seq / 8) as f64),
        Bound::Value(max_seq as f64),
        (steps as f64 * 0.6) as u64,
    ));
    c.routing = Routing::RandomLtd(LtdConfig::mslg(r_s, steps));
    c
}

fn main() -> dsde::Result<()> {
    let steps = scaled(40, 8);
    let slice = scaled(10, 3);
    let docs = scaled(800, 300) as usize;
    eprintln!("== sched_throughput: {steps}-step jobs, {slice}-step slices ==");
    let env = TrainEnv::new(docs, 7)?;
    let max_seq = env.rt.registry.family("gpt")?.max_seq;

    let mut baseline = RunConfig::baseline("gpt", steps, 3e-3);
    baseline.label = "gpt-baseline".into();
    baseline.seed = 1234;
    // ViT takes random-LTD only (no sequence curriculum), as in the paper.
    let mut vit = RunConfig::baseline("vit", steps, 3e-3);
    vit.label = "vit-ltd".into();
    vit.seed = 1234;
    vit.routing = Routing::RandomLtd(LtdConfig::mslg(5, steps));
    let cases = vec![
        composed("gpt", "gpt-composed", steps, max_seq, max_seq / 4),
        baseline,
        composed("bert", "bert-composed", steps, max_seq, max_seq / 4),
        vit,
    ];
    let n_jobs = cases.len();

    // ---- sequential reference (cold cache)
    env.rt.clear_cache();
    let cache0 = env.rt.cache_stats();
    let t0 = std::time::Instant::now();
    let sequential = run_cases(&env, cases.clone())?;
    let seq_wall = t0.elapsed().as_secs_f64();
    let seq_cache = env.rt.cache_stats().since(&cache0);

    // ---- scheduler path: same jobs, time-sliced on the shared runtime
    let dir = std::env::temp_dir().join(format!("dsde-sched-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    env.rt.clear_cache();
    let cache1 = env.rt.cache_stats();
    let mut sched = Scheduler::new(SchedulerConfig {
        max_active: n_jobs,
        default_slice: slice,
        quantum: slice,
        cleanup_done: true,
    });
    let mut ids = Vec::new();
    for mut cfg in cases {
        cfg.save_dir = dir.to_string_lossy().into_owned();
        ids.push(sched.submit(JobSpec::new(cfg))?);
    }
    let t1 = std::time::Instant::now();
    sched.drain(&env)?;
    let sched_wall = t1.elapsed().as_secs_f64();
    let sched_cache = env.rt.cache_stats().since(&cache1);
    let stats = sched.stats();

    let mut t = Table::new(&["job", "state", "slices", "preempt", "state hash", "drift"]);
    let mut identical = true;
    for (id, reference) in ids.iter().zip(&sequential) {
        let job = sched.job(*id).expect("submitted job");
        let (hash, drift) = match (&job.result, job.state) {
            (Some(r), JobState::Done) => {
                let ok = r.state_hash == reference.state_hash
                    && r.step_losses == reference.step_losses;
                (format!("{:016x}", r.state_hash), !ok)
            }
            _ => ("-".into(), true),
        };
        identical &= !drift;
        t.row(vec![
            reference.label.clone(),
            job.state.name().into(),
            job.slices.to_string(),
            job.preemptions.to_string(),
            hash,
            if drift { "DRIFT".into() } else { "ok".into() },
        ]);
    }
    println!("\nscheduler vs sequential ({n_jobs} jobs × {steps} steps, slice {slice}):");
    t.print();
    t.save_csv("sched_throughput")?;

    let overhead = sched_wall - seq_wall;
    let per_slice = overhead / (stats.slices.max(1) as f64);
    let hit_rate = |h: u64, m: u64| h as f64 / ((h + m).max(1) as f64);
    println!(
        "\nmakespan: sequential {seq_wall:.2}s, scheduled {sched_wall:.2}s \
         ({overhead:+.2}s; {} slices, {} preemptions, {:.0}ms/slice preemption overhead)",
        stats.slices,
        stats.preemptions,
        per_slice * 1e3
    );
    println!(
        "shared jit cache across tenants: sequential {}h/{}m ({:.0}%), \
         scheduled {}h/{}m ({:.0}%)",
        seq_cache.hits,
        seq_cache.misses,
        hit_rate(seq_cache.hits, seq_cache.misses) * 100.0,
        sched_cache.hits,
        sched_cache.misses,
        hit_rate(sched_cache.hits, sched_cache.misses) * 100.0
    );

    let report = Json::obj(vec![
        ("n_jobs", n_jobs.into()),
        ("steps_per_job", (steps as usize).into()),
        ("slice_steps", (slice as usize).into()),
        ("makespan_sequential_s", seq_wall.into()),
        ("makespan_scheduled_s", sched_wall.into()),
        ("slices", (stats.slices as usize).into()),
        ("preemptions", (stats.preemptions as usize).into()),
        ("preempt_overhead_s_per_slice", per_slice.into()),
        ("cache_hit_rate_scheduled", hit_rate(sched_cache.hits, sched_cache.misses).into()),
        ("bit_identical", identical.into()),
    ]);
    std::fs::create_dir_all("runs")?;
    std::fs::write("runs/BENCH_sched.json", report.to_string_compact())?;
    history_append("sched_throughput", &report)?;
    println!("report -> runs/BENCH_sched.json");

    println!(
        "\nshape check:\n  [{}] every time-sliced job is bit-identical to its \
         uninterrupted reference",
        if identical { "PASS" } else { "FAIL" }
    );
    let _ = std::fs::remove_dir_all(&dir);
    if !identical {
        // Enforcing, not advisory: time-slicing must be bit-neutral.
        std::process::exit(1);
    }
    Ok(())
}
