//! Fig. 2 reproduction — the cost–quality Pareto frontier.
//!
//! Sweeps data budgets from 1% to 100% (paper: 3B..300B tokens) and trains
//! baseline vs the composed CL_seqtru_voc+random-LTD solution at each
//! budget. Paper shape: the composed curve dominates at every budget, and
//! the quality level the baseline reaches at budget X is reached by the
//! composed run at a substantially smaller budget (the 12.5x headline).
//!
//! Also prints the Fig. 1 literature table (model/data scale trend) for
//! completeness — that figure is a survey plot, not an experiment.

use dsde::bench::{scaled, Table};
use dsde::exp::cases::fig2_pairs;
use dsde::exp::{relative_quality, run_cases};
use dsde::sim::cost::{PAPER_FULL_COST_USD, PAPER_FULL_HOURS};
use dsde::train::TrainEnv;

/// Fig. 1 data points (from the papers cited in the figure).
const FIG1: &[(&str, u64, f64, f64)] = &[
    // (model, year, params B, train tokens B)
    ("BERT-large", 2018, 0.34, 137.0),
    ("Megatron-LM", 2019, 8.3, 157.0),
    ("GPT-3", 2020, 175.0, 300.0),
    ("BLOOM", 2022, 176.0, 366.0),
    ("PaLM", 2022, 540.0, 780.0),
];

fn main() -> dsde::Result<()> {
    println!("Fig. 1 (literature survey): model and data scale grow together");
    let mut f1 = Table::new(&["model", "year", "params (B)", "tokens (B)"]);
    for (m, y, p, t) in FIG1 {
        f1.row(vec![m.to_string(), y.to_string(), format!("{p}"), format!("{t}")]);
    }
    f1.print();

    let full_steps = scaled(100, 24);
    let n_docs = scaled(800, 300) as usize;
    let fractions: Vec<f64> = if dsde::bench::quick_mode() {
        vec![0.25, 1.0]
    } else {
        vec![0.01, 0.02, 0.04, 0.08, 0.16, 0.32, 0.5, 1.0]
    };
    eprintln!("\n== Fig. 2: Pareto sweep over {} budgets (full={} steps) ==", fractions.len(), full_steps);
    let env = TrainEnv::new(n_docs, 7)?;
    let fam = env.rt.registry.family("gpt")?.clone();
    let pairs = fig2_pairs(full_steps, fam.max_seq, 1234, &fractions);

    let mut rows = Vec::new();
    for (f, base, comp) in pairs {
        let rs = run_cases(&env, vec![base, comp])?;
        rows.push((f, rs[0].clone(), rs[1].clone()));
    }
    let full_baseline = &rows.last().unwrap().1;
    let base_loss = full_baseline.final_eval_loss;
    let full_wall = full_baseline.wall_secs;

    let mut table = Table::new(&[
        "data %",
        "sim cost $ (baseline anchor)",
        "baseline quality %",
        "composed quality %",
    ]);
    let mut dominated = 0;
    for (f, b, c) in &rows {
        let qb = relative_quality(base_loss, b.final_eval_loss);
        let qc = relative_quality(base_loss, c.final_eval_loss);
        if qc >= qb - 0.05 {
            dominated += 1;
        }
        table.row(vec![
            format!("{:.0}%", f * 100.0),
            format!("{:.0}", PAPER_FULL_COST_USD * (b.wall_secs / full_wall)),
            format!("{qb:.1}"),
            format!("{qc:.1}"),
        ]);
    }
    println!("\nFig. 2 (reproduced; quality = inverse-loss % of full-data baseline)");
    table.print();
    table.save_csv("fig2_pareto")?;

    // headline: smallest composed budget reaching 95% quality vs baseline's
    let q95_base = rows
        .iter()
        .find(|(_, b, _)| relative_quality(base_loss, b.final_eval_loss) >= 95.0)
        .map(|(f, _, _)| *f);
    let q95_comp = rows
        .iter()
        .find(|(_, _, c)| relative_quality(base_loss, c.final_eval_loss) >= 95.0)
        .map(|(f, _, _)| *f);
    println!("\nheadline: budget to reach 95% quality:");
    if let (Some(fb), Some(fc)) = (q95_base, q95_comp) {
        println!(
            "  baseline {:.0}% of data (sim {:.0}h/${:.0}) vs composed {:.0}% (sim {:.0}h/${:.0}) -> {:.1}x saving",
            fb * 100.0,
            PAPER_FULL_HOURS * fb,
            PAPER_FULL_COST_USD * fb,
            fc * 100.0,
            PAPER_FULL_HOURS * fc,
            PAPER_FULL_COST_USD * fc,
            fb / fc
        );
    } else {
        println!("  (95% threshold not bracketed at this scale: base={q95_base:?} comp={q95_comp:?})");
    }
    println!("\nshape checks:");
    println!(
        "  [{}] composed >= baseline quality on {}/{} budgets",
        if dominated * 2 >= rows.len() { "PASS" } else { "FAIL" },
        dominated,
        rows.len()
    );
    Ok(())
}
