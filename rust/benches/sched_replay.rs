//! sched_replay — fleet-scale policy replay (ISSUE 7 tentpole).
//!
//! Drives 10⁴ (quick) / 10⁵ (full) synthetic `JobSpec`s through the real
//! admission + deficit-round-robin machinery **in closed form**
//! (`Scheduler::simulate_slice`: every pick, credit accrual, debit and
//! state transition is the production code path — only the training
//! itself is replaced by "the slice executes its budget"). Reports
//! ns/decision and a Jain fairness index over the slice log, and checks
//! the pick sequence bit-for-bit against an **independent reference
//! replay** — a from-scratch implementation of the documented policy
//! (full-scan admission sort + iterative DRR pass loop, none of the
//! scheduler's incremental-index or closed-form shortcuts). Any drift
//! exits non-zero, so CI goes red if an optimization ever changes a
//! scheduling decision. Emits `runs/BENCH_sched_replay.json`.
//!
//! `DSDE_BENCH_QUICK=1` shrinks the run for the CI smoke job.

use dsde::bench::{history_append, scaled, Table};
use dsde::config::json::Json;
use dsde::config::schema::RunConfig;
use dsde::orch::{JobSpec, Scheduler, SchedulerConfig};

const MAX_ACTIVE: usize = 16;
const SLICE: u64 = 16;
const QUANTUM: u64 = 4;

/// Deterministic spec mix: 3 priority classes, shares 1–4, 8–64 steps.
fn synth_specs(n: usize) -> Vec<JobSpec> {
    let mut state: u64 = 0x9e37_79b9_7f4a_7c15;
    let mut rng = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        state
    };
    (0..n)
        .map(|i| {
            let steps = 8 + rng() % 57;
            let mut c = RunConfig::baseline("gpt", steps, 1e-3);
            c.label = format!("synthetic-{i}");
            let mut spec = JobSpec::new(c);
            spec.priority = 1 + (rng() % 3) as u32;
            spec.share = 1 + (rng() % 4) as u32;
            spec
        })
        .collect()
}

/// Reference replay: the documented policy, implemented the slow obvious
/// way. Admission re-scans and re-sorts every runnable job per pick; the
/// DRR ring is walked pass by pass, accruing `quantum × share` per visit
/// until a job's credit covers its slice. Deliberately shares no code
/// (and no algorithmic shortcut) with `orch::scheduler`.
fn reference_replay(specs: &[JobSpec]) -> Vec<(u64, u64)> {
    struct RefJob {
        id: u64,
        priority: u32,
        share: u64,
        remaining: u64,
        deficit: i64,
    }
    let mut jobs: Vec<RefJob> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| RefJob {
            id: i as u64 + 1,
            priority: s.priority,
            share: s.share as u64,
            remaining: s.config.total_steps,
            deficit: 0,
        })
        .collect();
    let mut cursor: u64 = 0;
    let mut log = Vec::new();
    loop {
        // admission: full scan, sort by (priority desc, arrival asc)
        let mut runnable: Vec<usize> =
            (0..jobs.len()).filter(|&i| jobs[i].remaining > 0).collect();
        if runnable.is_empty() {
            break;
        }
        runnable.sort_by_key(|&i| (std::cmp::Reverse(jobs[i].priority), i));
        runnable.truncate(MAX_ACTIVE);
        let top = jobs[runnable[0]].priority;
        let ring: Vec<usize> =
            runnable.into_iter().filter(|&i| jobs[i].priority == top).collect();
        let start = ring.iter().position(|&i| jobs[i].id > cursor).unwrap_or(0);
        // iterative DRR: pass over the ring until credit covers a slice
        let winner = 'outer: loop {
            for k in 0..ring.len() {
                let i = ring[(start + k) % ring.len()];
                let accrual = QUANTUM
                    .saturating_mul(jobs[i].share)
                    .clamp(1, i64::MAX as u64) as i64;
                jobs[i].deficit = jobs[i].deficit.saturating_add(accrual);
                let cost = SLICE.min(jobs[i].remaining).min(i64::MAX as u64) as i64;
                if jobs[i].deficit >= cost {
                    break 'outer i;
                }
            }
        };
        let executed = SLICE.min(jobs[winner].remaining);
        jobs[winner].deficit -= executed as i64;
        jobs[winner].remaining -= executed;
        cursor = jobs[winner].id;
        log.push((jobs[winner].id, executed));
    }
    log
}

/// Jain fairness index over share-normalized service: J = (Σx)²/(n·Σx²),
/// x_i = steps job i received in the window / share_i. 1.0 = perfectly
/// proportional; 1/n = one job hogged everything.
fn jain(window: &[(u64, u64)], specs: &[JobSpec]) -> f64 {
    use std::collections::HashMap;
    let mut served: HashMap<u64, u64> = HashMap::new();
    for &(id, steps) in window {
        *served.entry(id).or_default() += steps;
    }
    let xs: Vec<f64> = served
        .iter()
        .map(|(&id, &steps)| steps as f64 / specs[id as usize - 1].share as f64)
        .collect();
    let n = xs.len() as f64;
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq == 0.0 {
        return 1.0;
    }
    sum * sum / (n * sq)
}

fn main() -> dsde::Result<()> {
    let n_jobs = scaled(100_000, 10_000) as usize;
    let n_ref = scaled(2_000, 500) as usize;
    let cfg = SchedulerConfig {
        max_active: MAX_ACTIVE,
        default_slice: SLICE,
        quantum: QUANTUM,
        cleanup_done: false,
    };
    eprintln!(
        "== sched_replay: {n_jobs} synthetic jobs, pool {MAX_ACTIVE}, \
         slice {SLICE}, quantum {QUANTUM} =="
    );

    // ---- drift check: indexed scheduler vs independent reference -----------
    let ref_specs = synth_specs(n_ref);
    let mut ref_sched = Scheduler::new(cfg.clone());
    for spec in ref_specs.clone() {
        ref_sched.submit(spec)?;
    }
    ref_sched.simulate_drain()?;
    let expected = reference_replay(&ref_specs);
    let got = ref_sched.slice_log();
    let drift = got != expected.as_slice();
    if drift {
        let at = got
            .iter()
            .zip(&expected)
            .position(|(a, b)| a != b)
            .unwrap_or_else(|| got.len().min(expected.len()));
        eprintln!(
            "DRIFT at slice {at}: scheduler {:?} vs reference {:?} \
             (log lengths {} vs {})",
            got.get(at),
            expected.get(at),
            got.len(),
            expected.len()
        );
    }

    // ---- fleet-scale replay: ns/decision + fairness ------------------------
    let specs = synth_specs(n_jobs);
    let mut sched = Scheduler::new(cfg);
    let t0 = std::time::Instant::now();
    for spec in specs.clone() {
        sched.submit(spec)?;
    }
    let submit_wall = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let slices = sched.simulate_drain()?;
    let drain_wall = t1.elapsed().as_secs_f64();
    assert!(sched.all_terminal(), "replay must drain every job");
    assert_eq!(sched.stats().completed, n_jobs as u64, "every job must complete");
    let ns_per_decision = drain_wall * 1e9 / slices.max(1) as f64;
    let ns_per_submit = submit_wall * 1e9 / n_jobs.max(1) as f64;
    // Fairness window: the first half of the log, where the pool is still
    // contended — a drained log as a whole only measures the spec mix.
    let log = sched.slice_log();
    let fairness = jain(&log[..log.len() / 2], &specs);

    let mut t = Table::new(&["metric", "value"]);
    t.row(vec!["jobs".into(), n_jobs.to_string()]);
    t.row(vec!["decisions (slices)".into(), slices.to_string()]);
    t.row(vec!["submit ns/job".into(), format!("{ns_per_submit:.0}")]);
    t.row(vec!["decision ns".into(), format!("{ns_per_decision:.0}")]);
    t.row(vec!["jain fairness".into(), format!("{fairness:.4}")]);
    t.row(vec![
        format!("drift vs reference ({n_ref} jobs)"),
        if drift { "DRIFT".into() } else { "none".into() },
    ]);
    println!("\nfleet-scale policy replay:");
    t.print();
    t.save_csv("sched_replay")?;

    let report = Json::obj(vec![
        ("n_jobs", n_jobs.into()),
        ("decisions", (slices as usize).into()),
        ("submit_ns_per_job", ns_per_submit.into()),
        ("decision_ns", ns_per_decision.into()),
        ("jain_fairness", fairness.into()),
        ("drift_check_jobs", n_ref.into()),
        ("pick_sequence_identical", (!drift).into()),
    ]);
    std::fs::create_dir_all("runs")?;
    std::fs::write("runs/BENCH_sched_replay.json", report.to_string_compact())?;
    history_append("sched_replay", &report)?;
    println!("report -> runs/BENCH_sched_replay.json");

    println!(
        "\nshape check:\n  [{}] pick sequence identical to the independent reference replay",
        if drift { "FAIL" } else { "PASS" }
    );
    if drift {
        // Enforcing, not advisory: optimizations must not change decisions.
        std::process::exit(1);
    }
    Ok(())
}
