//! dp_scaling — data-parallel replica-engine scaling (ISSUE 2).
//!
//! Runs the composed GPT case (CL seqtru+voc + random-LTD) on the replica
//! engine at n_replicas ∈ {1, 2, 4} over identical data/seed and reports,
//! per rank count: wall-clock per step, the all-reduce share of step time,
//! rank load imbalance, and the final state hash — which MUST be identical
//! across rows (the bench doubles as a visible rank-equivalence check; the
//! enforcing suite is tests/dp_equivalence.rs). A fused-path row is
//! included as the no-engine baseline for the engine's overhead.
//!
//! `DSDE_BENCH_QUICK=1` shrinks the run for the CI smoke job.

use dsde::bench::{scaled, Table};
use dsde::exp::cases::dp_scaling_cases;
use dsde::train::TrainEnv;

fn main() -> dsde::Result<()> {
    let steps = scaled(60, 10);
    let docs = scaled(800, 300) as usize;
    eprintln!("== dp_scaling: replica engine at n ∈ {{1, 2, 4}} ({steps} steps) ==");
    let env = TrainEnv::new(docs, 7)?;
    let fam = env.rt.registry.family("gpt")?.clone();

    let mut t = Table::new(&[
        "replicas",
        "step ms",
        "allreduce ms/step",
        "allreduce share",
        "imbalance",
        "eval loss",
        "state hash",
    ]);

    // fused baseline (n_replicas = 0): same schedule, single fused step
    let mut fused = dp_scaling_cases(steps, fam.max_seq, 1234, &[1])[0].clone();
    fused.n_replicas = 0;
    fused.label = "fused".into();
    let fr = env.run(fused)?;
    t.row(vec![
        "fused".into(),
        format!("{:.2}", fr.step_secs * 1e3),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{:.4}", fr.final_eval_loss),
        "-".into(),
    ]);

    let mut hashes = Vec::new();
    for cfg in dp_scaling_cases(steps, fam.max_seq, 1234, &[1, 2, 4]) {
        let n = cfg.n_replicas;
        let r = env.run(cfg)?;
        let exec_secs = r.step_secs * steps as f64;
        t.row(vec![
            n.to_string(),
            format!("{:.2}", r.step_secs * 1e3),
            format!("{:.3}", r.allreduce_secs * 1e3 / steps as f64),
            format!("{:.1}%", 100.0 * r.allreduce_secs / exec_secs.max(1e-12)),
            format!("{:.0}%", r.rank_imbalance * 100.0),
            format!("{:.4}", r.final_eval_loss),
            format!("{:016x}", r.state_hash),
        ]);
        hashes.push((n, r.state_hash, r.step_losses.clone()));
    }
    println!("\ndata-parallel scaling (composed GPT case, batch {} rows):", fam.batch);
    t.print();
    t.save_csv("dp_scaling")?;

    let (n1, h1, l1) = &hashes[0];
    assert_eq!(*n1, 1);
    let mut all_equal = true;
    for (n, h, l) in &hashes[1..] {
        if h != h1 || l != l1 {
            eprintln!("  dp{n}: state/loss diverged from dp1!");
            all_equal = false;
        }
    }
    println!(
        "\nshape check:\n  [{}] rank equivalence: final state + loss curve bit-identical for n ∈ {{1, 2, 4}}",
        if all_equal { "PASS" } else { "FAIL" }
    );
    if !all_equal {
        // Enforcing, not advisory: the CI bench-smoke job must go red on a
        // rank-equivalence break even before tests/dp_equivalence.rs runs.
        std::process::exit(1);
    }
    Ok(())
}
