//! Deterministic CPU test-double for the PJRT/XLA runtime.
//!
//! The offline vendor set carries no XLA native library, so this crate
//! re-implements the small slice of the `xla` API the dsde coordinator
//! uses (`Literal`, `PjRtClient`, `HloModuleProto`, executable load +
//! execute) as an interpreter over *surrogate HLO modules*: short
//! `key value` texts (synthesized in memory by `dsde`'s
//! `runtime/synth.rs`; `python/compile/gen_stub_artifacts.py` survives as
//! the byte-identical cross-check reference) that describe a trainable
//! softmax model per family instead of a lowered HLO graph.
//!
//! The surrogate semantics preserve everything the coordinator is tested
//! against (see DESIGN.md §Substitutions):
//!
//! * `*_init`    — seed-deterministic parameter init, zero Adam moments;
//! * `*_train`   — masked softmax cross-entropy + Adam on a per-layer
//!   additive logit model; random-LTD / TokenBypass keep-index inputs
//!   restrict which positions each middle layer processes (so token
//!   dropping genuinely changes per-layer compute and gradients);
//! * `*_eval`    — token-weighted loss sums (and ViT top-1 accuracy);
//! * `*_grad`    — the data-parallel step mode: *unnormalized* gradient
//!   sums over a shard of the batch, combined with a fixed
//!   pairwise-adjacent tree over rows (so rank-local sums are exact
//!   subtrees of the single-rank reduction), plus `[loss_sum, den]`;
//! * `*_apply`   — divide reduced gradients by the reduced denominator
//!   and run the shared Adam update on the full optimizer state.
//!
//! Everything is single-threaded and bit-deterministic from the inputs.

use std::borrow::Borrow;
use std::collections::HashMap;
use std::fmt;

// ---------------------------------------------------------------------------
// Errors

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error(msg.into()))
}

// ---------------------------------------------------------------------------
// Literals

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U32,
}

#[doc(hidden)]
#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::U32(v) => v.len(),
        }
    }

    fn ty(&self) -> ElementType {
        match self {
            Data::F32(_) => ElementType::F32,
            Data::I32(_) => ElementType::S32,
            Data::U32(_) => ElementType::U32,
        }
    }
}

/// Element types a `Literal` can hold.
pub trait NativeType: Copy + 'static {
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(d: &Data) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<&[Self]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<&[Self]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for u32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::U32(v)
    }
    fn unwrap(d: &Data) -> Option<&[Self]> {
        match d {
            Data::U32(v) => Some(v),
            _ => None,
        }
    }
}

/// Array shape metadata (dims only; layout is always dense row-major).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host literal: a dense typed array or a tuple of literals.
#[derive(Clone, Debug)]
pub enum Literal {
    Array { data: Data, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from a data slice.
    pub fn vec1<T: NativeType>(xs: &[T]) -> Literal {
        Literal::Array { data: T::wrap(xs.to_vec()), dims: vec![xs.len() as i64] }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        Literal::Array { data: T::wrap(vec![x]), dims: Vec::new() }
    }

    fn from_f32(data: Vec<f32>, dims: Vec<i64>) -> Literal {
        Literal::Array { data: Data::F32(data), dims }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match self {
            Literal::Array { data, .. } => {
                let n: i64 = dims.iter().product();
                if n as usize != data.len() {
                    return err(format!(
                        "reshape: {} elements into dims {:?}",
                        data.len(),
                        dims
                    ));
                }
                Ok(Literal::Array { data: data.clone(), dims: dims.to_vec() })
            }
            Literal::Tuple(_) => err("reshape: tuple literal"),
        }
    }

    pub fn element_count(&self) -> usize {
        match self {
            Literal::Array { data, .. } => data.len(),
            Literal::Tuple(parts) => parts.iter().map(|p| p.element_count()).sum(),
        }
    }

    pub fn size_bytes(&self) -> usize {
        // all supported element types are 4 bytes wide
        self.element_count() * 4
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { dims, .. } => Ok(ArrayShape { dims: dims.clone() }),
            Literal::Tuple(_) => err("array_shape: tuple literal"),
        }
    }

    pub fn ty(&self) -> Result<ElementType> {
        match self {
            Literal::Array { data, .. } => Ok(data.ty()),
            Literal::Tuple(_) => err("ty: tuple literal"),
        }
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        match self {
            Literal::Array { data, .. } => match T::unwrap(data) {
                Some(xs) if !xs.is_empty() => Ok(xs[0]),
                Some(_) => err("get_first_element: empty literal"),
                None => err("get_first_element: element type mismatch"),
            },
            Literal::Tuple(_) => err("get_first_element: tuple literal"),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { data, .. } => match T::unwrap(data) {
                Some(xs) => Ok(xs.to_vec()),
                None => err("to_vec: element type mismatch"),
            },
            Literal::Tuple(_) => err("to_vec: tuple literal"),
        }
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            Literal::Array { .. } => err("to_tuple: array literal"),
        }
    }
}

// ---------------------------------------------------------------------------
// Surrogate module ("HLO proto") parsing

/// Parsed surrogate module description.
#[derive(Clone, Debug, Default)]
struct Program {
    name: String,
    semantic: String,
    vocab: usize,
    d_model: usize,
    n_layers: usize,
    n_mid: usize,
    rows: usize,
    seq: usize,
    keep: usize,
    mode: String,
    pad_mask: bool,
    classes: usize,
    patch_dim: usize,
    gain: f32,
}

/// Stand-in for `HloModuleProto`: holds the parsed surrogate program.
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    program: Program,
}

impl HloModuleProto {
    /// Parse a surrogate module text file (`key value` lines; `#` comments).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading {path}: {e}")))?;
        Self::parse_text(&text, path)
    }

    /// Parse a surrogate module from in-memory text (the in-process
    /// synthesis path: no file ever exists for JIT-specialized variants).
    pub fn from_text(text: &str) -> Result<HloModuleProto> {
        Self::parse_text(text, "<memory>")
    }

    fn parse_text(text: &str, src: &str) -> Result<HloModuleProto> {
        let path = src;
        let mut fields: HashMap<String, String> = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
                continue;
            }
            let mut it = line.split_whitespace();
            let key = match it.next() {
                Some(k) => k,
                None => continue,
            };
            let val = it.next().unwrap_or("");
            fields.insert(key.to_string(), val.to_string());
        }
        if fields.get("dsde-hlo").map(String::as_str) != Some("1") {
            return err(format!("{path}: not a dsde surrogate HLO module"));
        }
        let get = |k: &str| fields.get(k).cloned().unwrap_or_default();
        let get_n = |k: &str| -> usize { fields.get(k).and_then(|v| v.parse().ok()).unwrap_or(0) };
        let program = Program {
            name: get("name"),
            semantic: get("semantic"),
            vocab: get_n("vocab"),
            d_model: get_n("d_model"),
            n_layers: get_n("n_layers"),
            n_mid: get_n("n_mid"),
            rows: get_n("rows"),
            seq: get_n("seq"),
            keep: get_n("keep"),
            mode: {
                let m = get("mode");
                if m.is_empty() {
                    "plain".to_string()
                } else {
                    m
                }
            },
            pad_mask: get_n("pad_mask") != 0,
            classes: get_n("classes"),
            patch_dim: get_n("patch_dim"),
            gain: fields
                .get("gain")
                .and_then(|v| v.parse().ok())
                .unwrap_or(16.0),
        };
        if program.semantic.is_empty() {
            return err(format!("{path}: missing 'semantic'"));
        }
        Ok(HloModuleProto { program })
    }
}

/// Stand-in for `XlaComputation`.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    program: Program,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { program: proto.program.clone() }
    }
}

// ---------------------------------------------------------------------------
// Client / executable / buffer

/// Stand-in for the PJRT CPU client.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        // "Compilation" validates the program shape table once up front.
        let p = &comp.program;
        match p.semantic.as_str() {
            "lm_init" | "lm_train" | "lm_eval" | "lm_grad" => {
                if p.vocab == 0 || p.n_layers < 3 {
                    return err(format!("{}: bad lm program", p.name));
                }
            }
            "vit_init" | "vit_train" | "vit_eval" | "vit_grad" => {
                if p.classes == 0 || p.patch_dim == 0 {
                    return err(format!("{}: bad vit program", p.name));
                }
            }
            "apply" => {
                if p.n_layers < 3 || (p.vocab == 0 && (p.classes == 0 || p.patch_dim == 0)) {
                    return err(format!("{}: bad apply program", p.name));
                }
            }
            s => return err(format!("{}: unknown semantic '{s}'", p.name)),
        }
        Ok(PjRtLoadedExecutable { program: comp.program.clone() })
    }
}

/// A device buffer holding one output literal.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// A loaded ("compiled") surrogate executable.
pub struct PjRtLoadedExecutable {
    program: Program,
}

impl PjRtLoadedExecutable {
    /// Execute with positional inputs; returns per-device output buffers
    /// (one device, one tuple buffer — mirroring the real API shape).
    pub fn execute<L: Borrow<Literal>>(&self, args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let lits: Vec<&Literal> = args.iter().map(|a| a.borrow()).collect();
        let out = run_program(&self.program, &lits)?;
        Ok(vec![vec![PjRtBuffer { lit: out }]])
    }
}

// ---------------------------------------------------------------------------
// Surrogate model semantics

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.99;
const ADAM_EPS: f32 = 1e-8;
const INIT_SCALE: f32 = 0.02;

/// splitmix64 — the stub's own deterministic generator (independent of the
/// coordinator's PCG so seeds don't alias).
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Rng {
        Rng(seed ^ 0x9e37_79b9_7f4a_7c15)
    }

    fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in [-1, 1).
    fn next_sym_f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (2.0 / (1u64 << 24) as f32) - 1.0
    }
}

/// ViT-family parameter layout? (The family-agnostic `apply` semantic has
/// no `vit_` prefix, so fall back on the field that distinguishes the
/// families: ViT programs carry classes/patch_dim and no vocabulary.)
fn vit_params(p: &Program) -> bool {
    p.semantic.starts_with("vit") || (p.vocab == 0 && p.classes > 0)
}

/// (len, dims) of each parameter tensor, in layout order.
fn param_shapes(p: &Program) -> Vec<(usize, Vec<i64>)> {
    let l = p.n_layers;
    let mut shapes = Vec::with_capacity(3 * l);
    let (rows_w, cols_w) = if vit_params(p) {
        (p.patch_dim, p.classes)
    } else {
        (p.vocab, p.vocab)
    };
    let bias = if vit_params(p) { p.classes } else { p.vocab };
    for _ in 0..l {
        shapes.push((rows_w * cols_w, vec![rows_w as i64, cols_w as i64]));
    }
    for _ in 0..l {
        shapes.push((bias, vec![bias as i64]));
    }
    for _ in 0..l {
        shapes.push((p.d_model, vec![p.d_model as i64]));
    }
    shapes
}

fn n_params(p: &Program) -> usize {
    3 * p.n_layers
}

fn run_program(p: &Program, args: &[&Literal]) -> Result<Literal> {
    match p.semantic.as_str() {
        "lm_init" | "vit_init" => run_init(p, args),
        "lm_train" => run_lm(p, args, true),
        "lm_eval" => run_lm(p, args, false),
        "lm_grad" => run_lm_grad(p, args),
        "vit_train" => run_vit(p, args, true),
        "vit_eval" => run_vit(p, args, false),
        "vit_grad" => run_vit_grad(p, args),
        "apply" => run_apply(p, args),
        s => err(format!("unknown semantic '{s}'")),
    }
}

fn want_args(p: &Program, got: usize, want: usize) -> Result<()> {
    if got != want {
        return err(format!("{}: expected {want} inputs, got {got}", p.name));
    }
    Ok(())
}

fn f32s<'a>(p: &Program, l: &'a Literal, what: &str, len: usize) -> Result<&'a [f32]> {
    match l {
        Literal::Array { data: Data::F32(v), .. } if v.len() == len => Ok(v),
        Literal::Array { data: Data::F32(v), .. } => err(format!(
            "{}: {what} has {} elements, expected {len}",
            p.name,
            v.len()
        )),
        _ => err(format!("{}: {what} must be an f32 array", p.name)),
    }
}

fn i32s<'a>(p: &Program, l: &'a Literal, what: &str, len: usize) -> Result<&'a [i32]> {
    match l {
        Literal::Array { data: Data::I32(v), .. } if v.len() == len => Ok(v),
        Literal::Array { data: Data::I32(v), .. } => err(format!(
            "{}: {what} has {} elements, expected {len}",
            p.name,
            v.len()
        )),
        _ => err(format!("{}: {what} must be an i32 array", p.name)),
    }
}

fn scalar_f32(p: &Program, l: &Literal, what: &str) -> Result<f32> {
    l.get_first_element::<f32>()
        .map_err(|e| Error(format!("{}: {what}: {e}", p.name)))
}

// ---- init -----------------------------------------------------------------

fn run_init(p: &Program, args: &[&Literal]) -> Result<Literal> {
    want_args(p, args.len(), 1)?;
    let seed = args[0]
        .get_first_element::<u32>()
        .map_err(|e| Error(format!("{}: seed: {e}", p.name)))? as u64;
    let shapes = param_shapes(p);
    let np = n_params(p);
    let l = p.n_layers;
    let mut out = Vec::with_capacity(3 * np);
    // params: W_l random (seed-dependent), b_l zero, g_l one
    for (ti, (len, dims)) in shapes.iter().enumerate() {
        let data = if ti < l {
            let mut rng = Rng::new(seed.wrapping_mul(0x1000_0001).wrapping_add(ti as u64));
            (0..*len)
                .map(|_| rng.next_sym_f32() * INIT_SCALE / l as f32)
                .collect()
        } else if ti < 2 * l {
            vec![0.0f32; *len]
        } else {
            vec![1.0f32; *len]
        };
        out.push(Literal::from_f32(data, dims.clone()));
    }
    // Adam moments start at zero
    for _ in 0..2 {
        for (len, dims) in &shapes {
            out.push(Literal::from_f32(vec![0.0; *len], dims.clone()));
        }
    }
    Ok(Literal::Tuple(out))
}

// ---- shared pieces --------------------------------------------------------

/// Per-middle-layer processed-position mask from the keep-index input.
/// `keep_idx` layout: ltd = `[n_mid, keep]` (independent per layer),
/// bypass = `[keep]` (one shared set).
fn processed_positions(
    p: &Program,
    keep_idx: Option<&[i32]>,
) -> Result<Vec<Vec<bool>>> {
    let mut proc = vec![vec![true; p.seq]; p.n_mid];
    let idx = match keep_idx {
        None => return Ok(proc),
        Some(idx) => idx,
    };
    for layer in proc.iter_mut() {
        for v in layer.iter_mut() {
            *v = false;
        }
    }
    let shared = p.mode == "bypass";
    for (mid, layer) in proc.iter_mut().enumerate() {
        let row = if shared { idx } else { &idx[mid * p.keep..(mid + 1) * p.keep] };
        for &j in row {
            if j < 0 || j as usize >= p.seq {
                return err(format!("{}: keep index {j} out of range", p.name));
            }
            layer[j as usize] = true;
        }
    }
    Ok(proc)
}

/// Stable softmax cross-entropy at one position. Fills `probs` with the
/// softmax distribution and returns the CE loss against `target`.
fn softmax_xent(logits: &[f32], target: usize, probs: &mut [f32]) -> f32 {
    let mut mx = f32::NEG_INFINITY;
    for &z in logits {
        if z > mx {
            mx = z;
        }
    }
    let mut sum = 0.0f32;
    for (pr, &z) in probs.iter_mut().zip(logits) {
        let e = (z - mx).exp();
        *pr = e;
        sum += e;
    }
    for pr in probs.iter_mut() {
        *pr /= sum;
    }
    sum.ln() + mx - logits[target]
}

struct AdamOut {
    state: Vec<Literal>,
    gnorm: f32,
}

/// Apply Adam to every parameter tensor given per-tensor gradients
/// (`None` = zero gradient: parameter and moments pass through).
#[allow(clippy::too_many_arguments)]
fn adam_update(
    p: &Program,
    args: &[&Literal],
    grads: &[Option<Vec<f32>>],
    t: f32,
    lr: f32,
) -> Result<AdamOut> {
    let shapes = param_shapes(p);
    let np = n_params(p);
    let t = if t < 1.0 { 1.0 } else { t };
    let bc1 = 1.0 - ADAM_B1.powf(t);
    let bc2 = 1.0 - ADAM_B2.powf(t);
    let step = lr * p.gain;
    let mut params_out = Vec::with_capacity(np);
    let mut m_out = Vec::with_capacity(np);
    let mut v_out = Vec::with_capacity(np);
    let mut gsq = 0.0f64;
    for ti in 0..np {
        let (len, dims) = &shapes[ti];
        let w = f32s(p, args[ti], "param", *len)?;
        let m = f32s(p, args[np + ti], "adam m", *len)?;
        let v = f32s(p, args[2 * np + ti], "adam v", *len)?;
        match &grads[ti] {
            None => {
                params_out.push(Literal::from_f32(w.to_vec(), dims.clone()));
                m_out.push(Literal::from_f32(m.to_vec(), dims.clone()));
                v_out.push(Literal::from_f32(v.to_vec(), dims.clone()));
            }
            Some(g) => {
                let mut wn = Vec::with_capacity(*len);
                let mut mn = Vec::with_capacity(*len);
                let mut vn = Vec::with_capacity(*len);
                for i in 0..*len {
                    let gi = g[i];
                    gsq += (gi as f64) * (gi as f64);
                    let mi = ADAM_B1 * m[i] + (1.0 - ADAM_B1) * gi;
                    let vi = ADAM_B2 * v[i] + (1.0 - ADAM_B2) * gi * gi;
                    let mhat = mi / bc1;
                    let vhat = vi / bc2;
                    wn.push(w[i] - step * mhat / (vhat.sqrt() + ADAM_EPS));
                    mn.push(mi);
                    vn.push(vi);
                }
                params_out.push(Literal::from_f32(wn, dims.clone()));
                m_out.push(Literal::from_f32(mn, dims.clone()));
                v_out.push(Literal::from_f32(vn, dims.clone()));
            }
        }
    }
    let mut state = params_out;
    state.extend(m_out);
    state.extend(v_out);
    Ok(AdamOut { state, gnorm: (gsq.sqrt()) as f32 })
}

// ---- language-model semantics --------------------------------------------

/// LM surrogate: per-layer additive bigram logits.
/// `logits(pos) = Σ_{layers processing pos} W_l[token] + b_l`
/// First/last layers always process every position; middle layers honor the
/// keep-index input in ltd/bypass variants.
fn run_lm(p: &Program, args: &[&Literal], train: bool) -> Result<Literal> {
    let np = n_params(p);
    let l = p.n_layers;
    let vocab = p.vocab;
    let n = p.rows * p.seq;
    let pad = usize::from(p.pad_mask);
    let dropping = train && p.mode != "plain";
    let want = if train {
        3 * np + 2 + 3 + pad + usize::from(dropping)
    } else {
        np + 3 + pad
    };
    want_args(p, args.len(), want)?;

    let (t, lr, base) = if train {
        (
            scalar_f32(p, args[3 * np], "t")?,
            scalar_f32(p, args[3 * np + 1], "lr")?,
            3 * np + 2,
        )
    } else {
        (0.0, 0.0, np)
    };
    let tokens = i32s(p, args[base], "tokens", n)?;
    let targets = i32s(p, args[base + 1], "targets", n)?;
    let mask = f32s(p, args[base + 2], "loss_mask", n)?;
    let keep_idx = if dropping {
        let len = if p.mode == "bypass" { p.keep } else { p.n_mid * p.keep };
        Some(i32s(p, args[base + 3 + pad], "keep_idx", len)?)
    } else {
        None
    };
    let proc = processed_positions(p, keep_idx)?;

    let w: Vec<&[f32]> = (0..l)
        .map(|i| f32s(p, args[i], "W", vocab * vocab))
        .collect::<Result<_>>()?;
    let b: Vec<&[f32]> = (0..l)
        .map(|i| f32s(p, args[l + i], "b", vocab))
        .collect::<Result<_>>()?;

    let msum: f32 = mask.iter().sum();
    let mut gw: Vec<Vec<f32>> = if train {
        (0..l).map(|_| vec![0.0; vocab * vocab]).collect()
    } else {
        Vec::new()
    };
    let mut gb: Vec<Vec<f32>> = if train {
        (0..l).map(|_| vec![0.0; vocab]).collect()
    } else {
        Vec::new()
    };

    let mut logits = vec![0.0f32; vocab];
    let mut probs = vec![0.0f32; vocab];
    let mut active = vec![true; l];
    let mut loss_sum = 0.0f64;

    for pos in 0..n {
        let m = mask[pos];
        if m <= 0.0 {
            continue;
        }
        let x = tokens[pos];
        let y = targets[pos];
        if x < 0 || x as usize >= vocab || y < 0 || y as usize >= vocab {
            return err(format!("{}: token id out of vocabulary at {pos}", p.name));
        }
        let (x, y) = (x as usize, y as usize);
        let j = pos % p.seq;
        for (li, a) in active.iter_mut().enumerate() {
            *a = li == 0 || li == l - 1 || proc[li - 1][j];
        }
        for z in logits.iter_mut() {
            *z = 0.0;
        }
        for li in 0..l {
            if !active[li] {
                continue;
            }
            let wrow = &w[li][x * vocab..(x + 1) * vocab];
            let bl = b[li];
            for v in 0..vocab {
                logits[v] += wrow[v] + bl[v];
            }
        }
        let ce = softmax_xent(&logits, y, &mut probs);
        loss_sum += (m * ce) as f64;
        if train {
            let coeff = m / msum.max(1.0);
            for li in 0..l {
                if !active[li] {
                    continue;
                }
                let grow = &mut gw[li][x * vocab..(x + 1) * vocab];
                let gbl = &mut gb[li];
                for v in 0..vocab {
                    let mut d = probs[v];
                    if v == y {
                        d -= 1.0;
                    }
                    let d = d * coeff;
                    grow[v] += d;
                    gbl[v] += d;
                }
            }
        }
    }

    if !train {
        return Ok(Literal::Tuple(vec![
            Literal::scalar(loss_sum as f32),
            Literal::scalar(msum),
        ]));
    }

    let mut grads: Vec<Option<Vec<f32>>> = Vec::with_capacity(np);
    for g in gw {
        grads.push(Some(g));
    }
    for g in gb {
        grads.push(Some(g));
    }
    for _ in 0..l {
        grads.push(None); // gamma tensors: inert in the surrogate
    }
    let adam = adam_update(p, args, &grads, t, lr)?;
    let loss = if msum > 0.0 { loss_sum as f32 / msum } else { 0.0 };
    let mut out = adam.state;
    out.push(Literal::scalar(loss));
    out.push(Literal::scalar(adam.gnorm));
    out.push(Literal::scalar(msum));
    Ok(Literal::Tuple(out))
}

// ---- data-parallel grad / apply semantics ---------------------------------

/// One subtree of the per-row gradient reduction: gradient sums for the
/// W and bias tensors of every layer plus the loss/denominator partials.
struct GradPart {
    gw: Vec<Vec<f32>>,
    gb: Vec<Vec<f32>>,
    loss: f32,
    den: f32,
}

impl GradPart {
    fn zeros(l: usize, wlen: usize, blen: usize) -> GradPart {
        GradPart {
            gw: (0..l).map(|_| vec![0.0; wlen]).collect(),
            gb: (0..l).map(|_| vec![0.0; blen]).collect(),
            loss: 0.0,
            den: 0.0,
        }
    }

    fn add(&mut self, o: &GradPart) {
        for (a, b) in self.gw.iter_mut().zip(&o.gw) {
            for (x, y) in a.iter_mut().zip(b) {
                *x += *y;
            }
        }
        for (a, b) in self.gb.iter_mut().zip(&o.gb) {
            for (x, y) in a.iter_mut().zip(b) {
                *x += *y;
            }
        }
        self.loss += o.loss;
        self.den += o.den;
    }
}

/// Fixed pairwise-adjacent tree fold over per-row partials. This MUST use
/// the same bracketing as the coordinator's cross-rank reduction
/// (dsde::runtime::collective::tree_reduce): level by level, adjacent
/// pairs combined in order, an odd trailing element carried up unchanged.
/// When shard boundaries align with subtree boundaries (equal shard sizes
/// that are powers of two), a rank's local fold is an exact subtree of the
/// single-rank fold — the bit-equivalence invariant of tests/dp_equivalence.
fn tree_fold(mut parts: Vec<GradPart>) -> GradPart {
    debug_assert!(!parts.is_empty());
    while parts.len() > 1 {
        let mut next = Vec::with_capacity(parts.len().div_ceil(2));
        let mut it = parts.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                a.add(&b);
            }
            next.push(a);
        }
        parts = next;
    }
    parts.pop().expect("non-empty parts")
}

/// Emit a reduced GradPart as the grad artifact's output tuple:
/// per-layer W grads, per-layer bias grads, zero gamma grads (inert in
/// the surrogate, exactly like the fused train path), then
/// `[loss_sum, den]`.
fn grad_outputs(p: &Program, total: GradPart) -> Literal {
    let shapes = param_shapes(p);
    let l = p.n_layers;
    let mut out = Vec::with_capacity(3 * l + 2);
    for (li, g) in total.gw.into_iter().enumerate() {
        out.push(Literal::Array { data: Data::F32(g), dims: shapes[li].1.clone() });
    }
    for (li, g) in total.gb.into_iter().enumerate() {
        out.push(Literal::Array { data: Data::F32(g), dims: shapes[l + li].1.clone() });
    }
    for li in 0..l {
        let (len, dims) = &shapes[2 * l + li];
        out.push(Literal::from_f32(vec![0.0; *len], dims.clone()));
    }
    out.push(Literal::scalar(total.loss));
    out.push(Literal::scalar(total.den));
    Literal::Tuple(out)
}

/// LM gradient shard: same forward math as `run_lm`, but gradients are
/// accumulated per row with coefficient `m` (NOT `m / msum` — the global
/// denominator is only known after the cross-rank reduction) and combined
/// with the fixed row tree. Loss and mask-sum partials follow the same
/// tree so every cross-rank quantity is bit-stable under resharding.
fn run_lm_grad(p: &Program, args: &[&Literal]) -> Result<Literal> {
    let np = n_params(p);
    let l = p.n_layers;
    let vocab = p.vocab;
    let n = p.rows * p.seq;
    let pad = usize::from(p.pad_mask);
    let dropping = p.mode != "plain";
    want_args(p, args.len(), np + 3 + pad + usize::from(dropping))?;

    let tokens = i32s(p, args[np], "tokens", n)?;
    let targets = i32s(p, args[np + 1], "targets", n)?;
    let mask = f32s(p, args[np + 2], "loss_mask", n)?;
    let keep_idx = if dropping {
        let len = if p.mode == "bypass" { p.keep } else { p.n_mid * p.keep };
        Some(i32s(p, args[np + 3 + pad], "keep_idx", len)?)
    } else {
        None
    };
    let proc = processed_positions(p, keep_idx)?;

    let w: Vec<&[f32]> = (0..l)
        .map(|i| f32s(p, args[i], "W", vocab * vocab))
        .collect::<Result<_>>()?;
    let b: Vec<&[f32]> = (0..l)
        .map(|i| f32s(p, args[l + i], "b", vocab))
        .collect::<Result<_>>()?;

    let mut logits = vec![0.0f32; vocab];
    let mut probs = vec![0.0f32; vocab];
    let mut active = vec![true; l];
    let mut row_parts: Vec<GradPart> = Vec::with_capacity(p.rows);

    for r in 0..p.rows {
        let mut part = GradPart::zeros(l, vocab * vocab, vocab);
        let mut row_loss = 0.0f32;
        for j in 0..p.seq {
            let pos = r * p.seq + j;
            let m = mask[pos];
            part.den += m;
            if m <= 0.0 {
                continue;
            }
            let x = tokens[pos];
            let y = targets[pos];
            if x < 0 || x as usize >= vocab || y < 0 || y as usize >= vocab {
                return err(format!("{}: token id out of vocabulary at {pos}", p.name));
            }
            let (x, y) = (x as usize, y as usize);
            for (li, a) in active.iter_mut().enumerate() {
                *a = li == 0 || li == l - 1 || proc[li - 1][j];
            }
            for z in logits.iter_mut() {
                *z = 0.0;
            }
            for li in 0..l {
                if !active[li] {
                    continue;
                }
                let wrow = &w[li][x * vocab..(x + 1) * vocab];
                let bl = b[li];
                for v in 0..vocab {
                    logits[v] += wrow[v] + bl[v];
                }
            }
            let ce = softmax_xent(&logits, y, &mut probs);
            row_loss += m * ce;
            for li in 0..l {
                if !active[li] {
                    continue;
                }
                let grow = &mut part.gw[li][x * vocab..(x + 1) * vocab];
                let gbl = &mut part.gb[li];
                for v in 0..vocab {
                    let mut d = probs[v];
                    if v == y {
                        d -= 1.0;
                    }
                    let d = d * m;
                    grow[v] += d;
                    gbl[v] += d;
                }
            }
        }
        part.loss = row_loss;
        row_parts.push(part);
    }
    Ok(grad_outputs(p, tree_fold(row_parts)))
}

/// ViT gradient shard: per-row gradients with coefficient 1 (the global
/// 1/rows normalization happens in `apply`); `den` counts rows.
fn run_vit_grad(p: &Program, args: &[&Literal]) -> Result<Literal> {
    let np = n_params(p);
    let l = p.n_layers;
    let classes = p.classes;
    let pd = p.patch_dim;
    let n_patches = p.seq - 1;
    let dropping = p.mode != "plain";
    want_args(p, args.len(), np + 2 + usize::from(dropping))?;

    let patches = f32s(p, args[np], "patches", p.rows * n_patches * pd)?;
    let labels = i32s(p, args[np + 1], "labels", p.rows)?;
    let keep_idx = if dropping {
        let len = if p.mode == "bypass" { p.keep } else { p.n_mid * p.keep };
        Some(i32s(p, args[np + 2], "keep_idx", len)?)
    } else {
        None
    };
    let proc = processed_positions(p, keep_idx)?;

    let w: Vec<&[f32]> = (0..l)
        .map(|i| f32s(p, args[i], "W", pd * classes))
        .collect::<Result<_>>()?;
    let b: Vec<&[f32]> = (0..l)
        .map(|i| f32s(p, args[l + i], "b", classes))
        .collect::<Result<_>>()?;

    let mut logits = vec![0.0f32; classes];
    let mut probs = vec![0.0f32; classes];
    let mut h = vec![vec![0.0f32; pd]; l];
    let mut row_parts: Vec<GradPart> = Vec::with_capacity(p.rows);

    for r in 0..p.rows {
        let mut part = GradPart::zeros(l, pd * classes, classes);
        let y = labels[r];
        if y < 0 || y as usize >= classes {
            return err(format!("{}: label out of range in row {r}", p.name));
        }
        let y = y as usize;
        let row = &patches[r * n_patches * pd..(r + 1) * n_patches * pd];
        for li in 0..l {
            let hl = &mut h[li];
            for v in hl.iter_mut() {
                *v = 0.0;
            }
            let mut count = 0usize;
            for j in 0..p.seq {
                let processed = li == 0 || li == l - 1 || proc[li - 1][j];
                if !processed {
                    continue;
                }
                count += 1;
                if j == 0 {
                    continue; // class token: zero feature
                }
                let pv = &row[(j - 1) * pd..j * pd];
                for (hv, &x) in hl.iter_mut().zip(pv) {
                    *hv += x;
                }
            }
            let denom = count.max(1) as f32;
            for hv in hl.iter_mut() {
                *hv /= denom;
            }
        }
        for z in logits.iter_mut() {
            *z = 0.0;
        }
        for li in 0..l {
            let hl = &h[li];
            let wl = w[li];
            let bl = b[li];
            for c in 0..classes {
                let mut z = bl[c];
                for (d, &hv) in hl.iter().enumerate() {
                    z += hv * wl[d * classes + c];
                }
                logits[c] += z;
            }
        }
        let ce = softmax_xent(&logits, y, &mut probs);
        part.loss = ce;
        part.den = 1.0;
        for li in 0..l {
            let hl = &h[li];
            let gwl = &mut part.gw[li];
            let gbl = &mut part.gb[li];
            for c in 0..classes {
                let mut d = probs[c];
                if c == y {
                    d -= 1.0;
                }
                gbl[c] += d;
                for (dd, &hv) in hl.iter().enumerate() {
                    gwl[dd * classes + c] += hv * d;
                }
            }
        }
        row_parts.push(part);
    }
    Ok(grad_outputs(p, tree_fold(row_parts)))
}

/// The shared optimizer step of the replica engine: normalize the reduced
/// gradients by the reduced denominator and apply Adam to the full state.
/// Inputs: `3·np` state + `[t, lr, den]` + `np` gradient tensors;
/// outputs: `3·np` state + `gnorm`. The gamma gradients arrive as zeros,
/// so gammas (and their moments) pass through numerically unchanged —
/// matching the fused train path's inert gamma handling.
fn run_apply(p: &Program, args: &[&Literal]) -> Result<Literal> {
    let np = n_params(p);
    want_args(p, args.len(), 3 * np + 3 + np)?;
    let t = scalar_f32(p, args[3 * np], "t")?;
    let lr = scalar_f32(p, args[3 * np + 1], "lr")?;
    let den = scalar_f32(p, args[3 * np + 2], "den")?.max(1.0);
    let shapes = param_shapes(p);
    let mut grads: Vec<Option<Vec<f32>>> = Vec::with_capacity(np);
    for ti in 0..np {
        let g = f32s(p, args[3 * np + 3 + ti], "grad", shapes[ti].0)?;
        grads.push(Some(g.iter().map(|x| x / den).collect()));
    }
    let adam = adam_update(p, args, &grads, t, lr)?;
    let mut out = adam.state;
    out.push(Literal::scalar(adam.gnorm));
    Ok(Literal::Tuple(out))
}

// ---- ViT semantics --------------------------------------------------------

/// ViT surrogate: per-layer mean-pooled linear classifier.
/// Position 0 is the class token (zero feature); positions `1..=n_patches`
/// carry the patch vectors. A middle layer pools only the positions it
/// processes (keep-index input), so random-LTD changes its feature.
fn run_vit(p: &Program, args: &[&Literal], train: bool) -> Result<Literal> {
    let np = n_params(p);
    let l = p.n_layers;
    let classes = p.classes;
    let pd = p.patch_dim;
    let n_patches = p.seq - 1;
    let dropping = train && p.mode != "plain";
    let want = if train {
        3 * np + 2 + 2 + usize::from(dropping)
    } else {
        np + 2
    };
    want_args(p, args.len(), want)?;

    let (t, lr, base) = if train {
        (
            scalar_f32(p, args[3 * np], "t")?,
            scalar_f32(p, args[3 * np + 1], "lr")?,
            3 * np + 2,
        )
    } else {
        (0.0, 0.0, np)
    };
    let patches = f32s(p, args[base], "patches", p.rows * n_patches * pd)?;
    let labels = i32s(p, args[base + 1], "labels", p.rows)?;
    let keep_idx = if dropping {
        let len = if p.mode == "bypass" { p.keep } else { p.n_mid * p.keep };
        Some(i32s(p, args[base + 2], "keep_idx", len)?)
    } else {
        None
    };
    let proc = processed_positions(p, keep_idx)?;

    let w: Vec<&[f32]> = (0..l)
        .map(|i| f32s(p, args[i], "W", pd * classes))
        .collect::<Result<_>>()?;
    let b: Vec<&[f32]> = (0..l)
        .map(|i| f32s(p, args[l + i], "b", classes))
        .collect::<Result<_>>()?;

    let mut gw: Vec<Vec<f32>> = if train {
        (0..l).map(|_| vec![0.0; pd * classes]).collect()
    } else {
        Vec::new()
    };
    let mut gb: Vec<Vec<f32>> = if train {
        (0..l).map(|_| vec![0.0; classes]).collect()
    } else {
        Vec::new()
    };

    let mut logits = vec![0.0f32; classes];
    let mut probs = vec![0.0f32; classes];
    let mut h = vec![vec![0.0f32; pd]; l];
    let mut loss_sum = 0.0f64;
    let mut correct = 0usize;

    for r in 0..p.rows {
        let y = labels[r];
        if y < 0 || y as usize >= classes {
            return err(format!("{}: label out of range in row {r}", p.name));
        }
        let y = y as usize;
        let row = &patches[r * n_patches * pd..(r + 1) * n_patches * pd];
        // per-layer mean-pooled features over the positions it processes
        for li in 0..l {
            let hl = &mut h[li];
            for v in hl.iter_mut() {
                *v = 0.0;
            }
            let mut count = 0usize;
            for j in 0..p.seq {
                let processed = li == 0 || li == l - 1 || proc[li - 1][j];
                if !processed {
                    continue;
                }
                count += 1;
                if j == 0 {
                    continue; // class token: zero feature
                }
                let pv = &row[(j - 1) * pd..j * pd];
                for (hv, &x) in hl.iter_mut().zip(pv) {
                    *hv += x;
                }
            }
            let denom = count.max(1) as f32;
            for hv in hl.iter_mut() {
                *hv /= denom;
            }
        }
        for z in logits.iter_mut() {
            *z = 0.0;
        }
        for li in 0..l {
            let hl = &h[li];
            let wl = w[li];
            let bl = b[li];
            for c in 0..classes {
                let mut z = bl[c];
                for (d, &hv) in hl.iter().enumerate() {
                    z += hv * wl[d * classes + c];
                }
                logits[c] += z;
            }
        }
        let ce = softmax_xent(&logits, y, &mut probs);
        loss_sum += ce as f64;
        let mut best = 0usize;
        for c in 1..classes {
            if logits[c] > logits[best] {
                best = c;
            }
        }
        if best == y {
            correct += 1;
        }
        if train {
            let coeff = 1.0 / p.rows as f32;
            for li in 0..l {
                let hl = &h[li];
                let gwl = &mut gw[li];
                let gbl = &mut gb[li];
                for c in 0..classes {
                    let mut d = probs[c];
                    if c == y {
                        d -= 1.0;
                    }
                    let d = d * coeff;
                    gbl[c] += d;
                    for (dd, &hv) in hl.iter().enumerate() {
                        gwl[dd * classes + c] += hv * d;
                    }
                }
            }
        }
    }

    if !train {
        return Ok(Literal::Tuple(vec![
            Literal::scalar(loss_sum as f32),
            Literal::scalar(p.rows as f32),
            Literal::scalar(correct as f32),
        ]));
    }

    let mut grads: Vec<Option<Vec<f32>>> = Vec::with_capacity(np);
    for g in gw {
        grads.push(Some(g));
    }
    for g in gb {
        grads.push(Some(g));
    }
    for _ in 0..l {
        grads.push(None);
    }
    let adam = adam_update(p, args, &grads, t, lr)?;
    let loss = loss_sum as f32 / p.rows.max(1) as f32;
    let mut out = adam.state;
    out.push(Literal::scalar(loss));
    out.push(Literal::scalar(adam.gnorm));
    out.push(Literal::scalar(p.rows as f32));
    Ok(Literal::Tuple(out))
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn lm_program(mode: &str, keep: usize) -> Program {
        Program {
            name: "test_lm".into(),
            semantic: "lm_train".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 4,
            n_mid: 2,
            rows: 2,
            seq: 4,
            keep,
            mode: mode.into(),
            pad_mask: false,
            classes: 0,
            patch_dim: 0,
            gain: 16.0,
        }
    }

    fn init_state(p: &Program, seed: u32) -> Vec<Literal> {
        let mut ip = p.clone();
        ip.semantic = if p.semantic.starts_with("vit") {
            "vit_init".into()
        } else {
            "lm_init".into()
        };
        let seed_lit = Literal::scalar(seed);
        run_init(&ip, &[&seed_lit]).unwrap().to_tuple().unwrap()
    }

    #[test]
    fn literal_roundtrips() {
        let l = Literal::vec1(&[1i32, 2, 3, 4]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(l.ty().unwrap(), ElementType::S32);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
        assert!(l.to_vec::<f32>().is_err());
        assert_eq!(Literal::scalar(2.5f32).get_first_element::<f32>().unwrap(), 2.5);
        assert_eq!(Literal::scalar(7u32).get_first_element::<u32>().unwrap(), 7);
        assert!(Literal::vec1(&[1.0f32]).reshape(&[3]).is_err());
    }

    #[test]
    fn init_deterministic_and_seed_sensitive() {
        let p = lm_program("plain", 4);
        let a = init_state(&p, 1);
        let b = init_state(&p, 1);
        let c = init_state(&p, 2);
        assert_eq!(a.len(), 36);
        assert_eq!(a[0].to_vec::<f32>().unwrap(), b[0].to_vec::<f32>().unwrap());
        assert_ne!(a[0].to_vec::<f32>().unwrap(), c[0].to_vec::<f32>().unwrap());
        assert!(a[12].to_vec::<f32>().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn train_reduces_loss_on_repeated_batch() {
        let p = lm_program("plain", 4);
        let mut state = init_state(&p, 0);
        let n = p.rows * p.seq;
        let tokens = Literal::vec1(&(0..n as i32).map(|i| i % 16).collect::<Vec<_>>());
        let targets = Literal::vec1(&(0..n as i32).map(|i| (i + 3) % 16).collect::<Vec<_>>());
        let mask = Literal::vec1(&vec![1.0f32; n]);
        let mut losses = Vec::new();
        for t in 1..=10 {
            let tl = Literal::scalar(t as f32);
            let lrl = Literal::scalar(5e-3f32);
            let mut args: Vec<&Literal> = state.iter().collect();
            args.push(&tl);
            args.push(&lrl);
            args.push(&tokens);
            args.push(&targets);
            args.push(&mask);
            let out = run_lm(&p, &args, true).unwrap().to_tuple().unwrap();
            losses.push(out[36].get_first_element::<f32>().unwrap());
            state = out.into_iter().take(36).collect();
        }
        assert!(losses[0] > 2.0, "near ln(16) at init: {losses:?}");
        assert!(losses[9] < losses[0] * 0.5, "{losses:?}");
    }

    #[test]
    fn ltd_keep_indices_change_gradient_scope() {
        let p = lm_program("ltd", 2);
        let state = init_state(&p, 0);
        let n = p.rows * p.seq;
        let tokens = Literal::vec1(&vec![5i32; n]);
        let targets = Literal::vec1(&vec![6i32; n]);
        let mask = Literal::vec1(&vec![1.0f32; n]);
        let tl = Literal::scalar(1.0f32);
        let lrl = Literal::scalar(1e-3f32);
        let keep = Literal::vec1(&[0i32, 1, 2, 3]).reshape(&[2, 2]).unwrap();
        let mut args: Vec<&Literal> = state.iter().collect();
        args.push(&tl);
        args.push(&lrl);
        args.push(&tokens);
        args.push(&targets);
        args.push(&mask);
        args.push(&keep);
        let out = run_lm(&p, &args, true).unwrap().to_tuple().unwrap();
        assert_eq!(out.len(), 39);
        let loss = out[36].get_first_element::<f32>().unwrap();
        assert!(loss.is_finite() && loss > 0.0);
    }

    /// Cross-rank tree reduce for the tests: pairwise-adjacent, the same
    /// bracketing as `tree_fold` / dsde::runtime::collective::tree_reduce.
    fn reduce_outputs(mut ranks: Vec<Vec<Literal>>) -> Vec<Literal> {
        while ranks.len() > 1 {
            let mut next = Vec::new();
            let mut it = ranks.into_iter();
            while let Some(mut a) = it.next() {
                if let Some(b) = it.next() {
                    for (x, y) in a.iter_mut().zip(&b) {
                        let mut xv = x.to_vec::<f32>().unwrap();
                        let yv = y.to_vec::<f32>().unwrap();
                        for (xi, yi) in xv.iter_mut().zip(&yv) {
                            *xi += *yi;
                        }
                        let dims = x.array_shape().unwrap().dims().to_vec();
                        *x = Literal::from_f32(xv, dims);
                    }
                }
                next.push(a);
            }
            ranks = next;
        }
        ranks.pop().unwrap()
    }

    fn bits(lits: &[Literal]) -> Vec<Vec<u32>> {
        lits.iter()
            .map(|l| l.to_vec::<f32>().unwrap().iter().map(|x| x.to_bits()).collect())
            .collect()
    }

    #[test]
    fn lm_grad_shards_tree_reduce_bit_identical() {
        // The dp-equivalence invariant at interpreter level: a full-batch
        // grad equals the tree-reduction of aligned shard grads, bitwise.
        let mut pfull = lm_program("plain", 4);
        pfull.semantic = "lm_grad".into();
        pfull.rows = 8;
        let params: Vec<Literal> = init_state(&pfull, 5).into_iter().take(12).collect();
        let n = pfull.rows * pfull.seq;
        let tokens: Vec<i32> = (0..n as i32).map(|i| (i * 7 + 3) % 16).collect();
        let targets: Vec<i32> = (0..n as i32).map(|i| (i * 5 + 1) % 16).collect();
        let mask: Vec<f32> = (0..n).map(|i| if i % 5 == 0 { 0.0 } else { 1.0 }).collect();

        let run_rows = |row0: usize, rows: usize| -> Vec<Literal> {
            let mut p = pfull.clone();
            p.rows = rows;
            let m = rows * p.seq;
            let t = Literal::vec1(&tokens[row0 * p.seq..row0 * p.seq + m]);
            let g = Literal::vec1(&targets[row0 * p.seq..row0 * p.seq + m]);
            let mk = Literal::vec1(&mask[row0 * p.seq..row0 * p.seq + m]);
            let mut args: Vec<&Literal> = params.iter().collect();
            args.push(&t);
            args.push(&g);
            args.push(&mk);
            run_lm_grad(&p, &args).unwrap().to_tuple().unwrap()
        };

        let full = run_rows(0, 8);
        assert_eq!(full.len(), 14, "12 grads + loss_sum + den");
        for n_ranks in [2usize, 4, 8] {
            let s = 8 / n_ranks;
            let shards: Vec<Vec<Literal>> =
                (0..n_ranks).map(|r| run_rows(r * s, s)).collect();
            let combined = reduce_outputs(shards);
            assert_eq!(
                bits(&full),
                bits(&combined),
                "lm grad not bit-identical at {n_ranks} ranks"
            );
        }
        // den = mask sum, loss positive
        let den = full[13].get_first_element::<f32>().unwrap();
        assert_eq!(den, mask.iter().sum::<f32>());
        assert!(full[12].get_first_element::<f32>().unwrap() > 0.0);
    }

    #[test]
    fn lm_grad_ltd_mode_restricts_middle_layers() {
        let mut p = lm_program("ltd", 2);
        p.semantic = "lm_grad".into();
        let params: Vec<Literal> = init_state(&p, 2).into_iter().take(12).collect();
        let n = p.rows * p.seq;
        let tokens = Literal::vec1(&vec![5i32; n]);
        let targets = Literal::vec1(&vec![6i32; n]);
        let mask = Literal::vec1(&vec![1.0f32; n]);
        let keep = Literal::vec1(&[0i32, 1, 2, 3]).reshape(&[2, 2]).unwrap();
        let mut args: Vec<&Literal> = params.iter().collect();
        args.push(&tokens);
        args.push(&targets);
        args.push(&mask);
        args.push(&keep);
        let out = run_lm_grad(&p, &args).unwrap().to_tuple().unwrap();
        assert_eq!(out.len(), 14);
        // middle layer 1 (W index 1) only processed positions {0,1}: its
        // gradient restricted to rows of W for token 5 still nonzero, but
        // overall must differ from the always-active first layer's.
        assert_ne!(bits(&out[0..1]), bits(&out[1..2]));
    }

    #[test]
    fn vit_grad_shards_tree_reduce_bit_identical() {
        let p = Program {
            name: "test_vit_grad".into(),
            semantic: "vit_grad".into(),
            vocab: 0,
            d_model: 8,
            n_layers: 4,
            n_mid: 2,
            rows: 4,
            seq: 5,
            keep: 5,
            mode: "plain".into(),
            pad_mask: false,
            classes: 3,
            patch_dim: 6,
            gain: 16.0,
        };
        let params: Vec<Literal> = init_state(&p, 3).into_iter().take(12).collect();
        let n_patches = p.seq - 1;
        let patches: Vec<f32> = (0..p.rows * n_patches * p.patch_dim)
            .map(|i| ((i % 11) as f32 - 5.0) * 0.13)
            .collect();
        let labels = [0i32, 1, 2, 0];
        let run_rows = |row0: usize, rows: usize| -> Vec<Literal> {
            let mut sp = p.clone();
            sp.rows = rows;
            let stride = n_patches * sp.patch_dim;
            let pv = Literal::vec1(&patches[row0 * stride..(row0 + rows) * stride]);
            let lv = Literal::vec1(&labels[row0..row0 + rows]);
            let mut args: Vec<&Literal> = params.iter().collect();
            args.push(&pv);
            args.push(&lv);
            run_vit_grad(&sp, &args).unwrap().to_tuple().unwrap()
        };
        let full = run_rows(0, 4);
        for n_ranks in [2usize, 4] {
            let s = 4 / n_ranks;
            let shards: Vec<Vec<Literal>> =
                (0..n_ranks).map(|r| run_rows(r * s, s)).collect();
            assert_eq!(
                bits(&full),
                bits(&reduce_outputs(shards)),
                "vit grad not bit-identical at {n_ranks} ranks"
            );
        }
        assert_eq!(full[13].get_first_element::<f32>().unwrap(), 4.0, "den counts rows");
    }

    #[test]
    fn apply_consumes_reduced_grads_and_keeps_gamma_inert() {
        let mut p = lm_program("plain", 4);
        p.semantic = "lm_grad".into();
        let state = init_state(&p, 9);
        let params: Vec<Literal> = state.iter().take(12).cloned().collect();
        let n = p.rows * p.seq;
        let tokens = Literal::vec1(&(0..n as i32).map(|i| i % 16).collect::<Vec<_>>());
        let targets = Literal::vec1(&(0..n as i32).map(|i| (i + 2) % 16).collect::<Vec<_>>());
        let mask = Literal::vec1(&vec![1.0f32; n]);
        let mut args: Vec<&Literal> = params.iter().collect();
        args.push(&tokens);
        args.push(&targets);
        args.push(&mask);
        let gout = run_lm_grad(&p, &args).unwrap().to_tuple().unwrap();
        let den = gout[13].clone();
        let grads: Vec<Literal> = gout.into_iter().take(12).collect();

        let mut ap = p.clone();
        ap.semantic = "apply".into();
        let t = Literal::scalar(1.0f32);
        let lr = Literal::scalar(5e-3f32);
        let mut aargs: Vec<&Literal> = state.iter().collect();
        aargs.push(&t);
        aargs.push(&lr);
        aargs.push(&den);
        aargs.extend(grads.iter());
        let out = run_apply(&ap, &aargs).unwrap().to_tuple().unwrap();
        assert_eq!(out.len(), 37, "36 state + gnorm");
        let gnorm = out[36].get_first_element::<f32>().unwrap();
        assert!(gnorm.is_finite() && gnorm > 0.0);
        // W0 moved, gamma (tensor 8..12) and its moments unchanged
        assert_ne!(bits(&state[0..1]), bits(&out[0..1]));
        assert_eq!(bits(&state[8..12]), bits(&out[8..12]));
        assert_eq!(bits(&state[20..24]), bits(&out[20..24]));
    }

    #[test]
    fn eval_token_weighted() {
        let mut p = lm_program("plain", 4);
        p.semantic = "lm_eval".into();
        let state = init_state(&p, 0);
        let n = p.rows * p.seq;
        let tokens = Literal::vec1(&vec![3i32; n]);
        let targets = Literal::vec1(&vec![4i32; n]);
        let mask_v: Vec<f32> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { 0.0 }).collect();
        let mask = Literal::vec1(&mask_v);
        let mut args: Vec<&Literal> = state[..12].iter().collect();
        args.push(&tokens);
        args.push(&targets);
        args.push(&mask);
        let out = run_lm(&p, &args, false).unwrap().to_tuple().unwrap();
        let loss_sum = out[0].get_first_element::<f32>().unwrap();
        let tok = out[1].get_first_element::<f32>().unwrap();
        assert_eq!(tok, (n / 2) as f32);
        let mean = loss_sum / tok;
        assert!((mean - (16f32).ln()).abs() < 0.5, "{mean}");
    }

    #[test]
    fn vit_train_and_eval() {
        let p = Program {
            name: "test_vit".into(),
            semantic: "vit_train".into(),
            vocab: 0,
            d_model: 8,
            n_layers: 4,
            n_mid: 2,
            rows: 4,
            seq: 5,
            keep: 5,
            mode: "plain".into(),
            pad_mask: false,
            classes: 3,
            patch_dim: 6,
            gain: 16.0,
        };
        let mut state = init_state(&p, 3);
        let n_patches = p.seq - 1;
        let patches_v: Vec<f32> = (0..p.rows * n_patches * p.patch_dim)
            .map(|i| ((i % 7) as f32 - 3.0) * 0.1)
            .collect();
        let patches = Literal::vec1(&patches_v);
        let labels = Literal::vec1(&[0i32, 1, 2, 0]);
        for t in 1..=5 {
            let tl = Literal::scalar(t as f32);
            let lrl = Literal::scalar(1e-2f32);
            let mut args: Vec<&Literal> = state.iter().collect();
            args.push(&tl);
            args.push(&lrl);
            args.push(&patches);
            args.push(&labels);
            let out = run_vit(&p, &args, true).unwrap().to_tuple().unwrap();
            let loss = out[36].get_first_element::<f32>().unwrap();
            assert!(loss.is_finite());
            state = out.into_iter().take(36).collect();
        }
        let mut ep = p.clone();
        ep.semantic = "vit_eval".into();
        let mut args: Vec<&Literal> = state[..12].iter().collect();
        args.push(&patches);
        args.push(&labels);
        let out = run_vit(&ep, &args, false).unwrap().to_tuple().unwrap();
        let count = out[1].get_first_element::<f32>().unwrap();
        let correct = out[2].get_first_element::<f32>().unwrap();
        assert_eq!(count, 4.0);
        assert!((0.0..=4.0).contains(&correct));
    }
}
