"""L1 Pallas fused log-softmax + cross-entropy kernel with a custom VJP.

Fuses the vocabulary-projection loss tail of every family: a row-blocked
kernel computes per-row cross-entropy and saves the logsumexp; the backward
kernel forms ``(softmax(logits) - onehot(target)) * dloss`` without ever
materializing the probability matrix in the autodiff graph.

TPU mapping: rows are tiled in blocks of ``ROW_BLOCK`` so a block of
[ROW_BLOCK, V] logits (V ≤ 4096) stays within VMEM; the one-hot compare is a
VPU-friendly iota-equality, not a gather.

Correctness oracles: :func:`compile.kernels.ref.softmax_xent_ref` and
:func:`compile.kernels.ref.softmax_xent_grad_ref`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 64


def _row_block(n):
    return min(ROW_BLOCK, n)


def _fwd_kernel(logits_ref, tgt_ref, loss_ref, lse_ref):
    logits = logits_ref[...]
    tgt = tgt_ref[...]
    m = jnp.max(logits, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[:, None]), axis=-1))
    v = logits.shape[-1]
    onehot = jnp.arange(v)[None, :] == tgt[:, None]
    picked = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    loss_ref[...] = lse - picked
    lse_ref[...] = lse


def _bwd_kernel(logits_ref, tgt_ref, lse_ref, dloss_ref, dlogits_ref):
    logits = logits_ref[...]
    tgt = tgt_ref[...]
    lse = lse_ref[...]
    dloss = dloss_ref[...]
    p = jnp.exp(logits - lse[:, None])
    v = logits.shape[-1]
    onehot = (jnp.arange(v)[None, :] == tgt[:, None]).astype(logits.dtype)
    dlogits_ref[...] = (p - onehot) * dloss[:, None]


def _specs(n, v):
    rb = _row_block(n)
    grid = (n // rb,) if n % rb == 0 else ((n + rb - 1) // rb,)
    mat = pl.BlockSpec((rb, v), lambda i: (i, 0))
    row = pl.BlockSpec((rb,), lambda i: (i,))
    return grid, mat, row


def _xent_fwd_p(logits, targets):
    n, v = logits.shape
    grid, mat, row = _specs(n, v)
    loss, lse = pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[mat, row],
        out_specs=[row, row],
        out_shape=[
            jax.ShapeDtypeStruct((n,), logits.dtype),
            jax.ShapeDtypeStruct((n,), logits.dtype),
        ],
        interpret=True,
    )(logits, targets)
    return loss, lse


def _xent_bwd_p(logits, targets, lse, dloss):
    n, v = logits.shape
    grid, mat, row = _specs(n, v)
    return pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[mat, row, row, row],
        out_specs=mat,
        out_shape=jax.ShapeDtypeStruct((n, v), logits.dtype),
        interpret=True,
    )(logits, targets, lse, dloss)


@jax.custom_vjp
def softmax_xent(logits, targets):
    """Per-row softmax cross-entropy via the Pallas kernels.

    logits: [N, V] float; targets: [N] int32. Returns per-row loss [N].
    Differentiable w.r.t. logits only.
    """
    loss, _ = _xent_fwd_p(logits, targets)
    return loss


def _xent_vjp_fwd(logits, targets):
    loss, lse = _xent_fwd_p(logits, targets)
    return loss, (logits, targets, lse)


def _xent_vjp_bwd(res, dloss):
    logits, targets, lse = res
    dlogits = _xent_bwd_p(logits, targets, lse, dloss)
    return dlogits, None


softmax_xent.defvjp(_xent_vjp_fwd, _xent_vjp_bwd)
