"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle to float32 tolerance under pytest (see
python/tests/test_kernels.py, which also sweeps shapes with hypothesis).
They are also used by the L2 model tests to cross-check full forward passes.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, pad_mask=None, causal=True):
    """Reference multi-head scaled-dot-product attention.

    q, k, v: [B, H, S, D]
    pad_mask: optional [B, S] float (1 = valid key, 0 = padding)
    causal:   apply lower-triangular mask
    returns:  [B, H, S, D]
    """
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(d))
    if causal:
        tri = jnp.tril(jnp.ones((q.shape[2], k.shape[2]), dtype=bool))
        s = jnp.where(tri[None, None], s, NEG_INF)
    if pad_mask is not None:
        s = jnp.where(pad_mask[:, None, None, :] > 0, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def softmax_xent_ref(logits, targets):
    """Reference per-row softmax cross-entropy.

    logits: [N, V], targets: [N] int32
    returns: per-row loss [N]
    """
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, targets[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return lse - picked


def softmax_xent_grad_ref(logits, targets, dloss):
    """Reference gradient of softmax_xent_ref w.r.t. logits."""
    p = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    return (p - onehot) * dloss[:, None]
