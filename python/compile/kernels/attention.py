"""L1 Pallas attention kernel (forward + backward) with a custom VJP.

This is the compute hot spot of every transformer family in the repo. It is
written TPU-style and lowered with ``interpret=True`` so the emitted HLO runs
on the CPU PJRT client (real-TPU lowering produces a Mosaic custom-call the
CPU plugin cannot execute — see DESIGN.md §Hardware-Adaptation).

TPU mapping of the paper's GPU-era compute:

* one grid point per (batch, head) — the analogue of a CUDA thread block;
* each grid point stages a full (S, D) q/k/v tile through VMEM via
  ``BlockSpec`` (S ≤ 128, D ≤ 64 keeps every operand tile ≤ 32 KiB, well
  inside a 16 MiB VMEM budget with double buffering);
* the inner contractions (``q @ k.T``, ``p @ v``) are MXU-shaped
  ``jnp.dot`` ops in f32 (bf16-ready).

The forward kernel also emits the per-row logsumexp so the backward kernel
can rematerialize the probability matrix flash-attention-style instead of
storing the S×S attention map in HBM.

Correctness oracle: :func:`compile.kernels.ref.attention_ref` (pytest +
hypothesis sweeps in python/tests/test_kernels.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _scores(q, k, causal, pad=None):
    """Masked scaled scores for one (batch, head) tile: [S_q, S_k]."""
    d = q.shape[-1]
    s = jnp.dot(q, k.T) / jnp.sqrt(jnp.float32(d))
    if causal:
        sq, sk = q.shape[0], k.shape[0]
        tri = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(tri, s, NEG_INF)
    if pad is not None:
        s = jnp.where(pad[None, :] > 0, s, NEG_INF)
    return s


def _fwd_kernel(causal, has_pad, *refs):
    if has_pad:
        q_ref, k_ref, v_ref, pad_ref, o_ref, lse_ref = refs
        pad = pad_ref[...]
    else:
        q_ref, k_ref, v_ref, o_ref, lse_ref = refs
        pad = None
    # Accumulate in f32 (MXU-style), cast back to the storage dtype.
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    s = _scores(q, k, causal, pad)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[:, None])
    l = jnp.sum(p, axis=-1)
    o_ref[...] = (jnp.dot(p, v) / l[:, None]).astype(o_ref.dtype)
    lse_ref[...] = (m + jnp.log(l)).astype(lse_ref.dtype)


def _bwd_kernel(causal, has_pad, *refs):
    if has_pad:
        q_ref, k_ref, v_ref, pad_ref, o_ref, lse_ref, do_ref, dq_ref, dk_ref, dv_ref = refs
        pad = pad_ref[...]
    else:
        q_ref, k_ref, v_ref, o_ref, lse_ref, do_ref, dq_ref, dk_ref, dv_ref = refs
        pad = None
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    o = o_ref[...].astype(jnp.float32)
    lse = lse_ref[...].astype(jnp.float32)
    do = do_ref[...].astype(jnp.float32)
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    s = _scores(q, k, causal, pad)
    # Rematerialize P from the saved logsumexp (flash-attention backward).
    p = jnp.exp(s - lse[:, None])
    dv_ref[...] = jnp.dot(p.T, do).astype(dv_ref.dtype)
    dp = jnp.dot(do, v.T)
    delta = jnp.sum(do * o, axis=-1)
    ds = p * (dp - delta[:, None]) * scale
    dq_ref[...] = jnp.dot(ds, k).astype(dq_ref.dtype)
    dk_ref[...] = jnp.dot(ds.T, q).astype(dk_ref.dtype)


def _bh_spec(s, d):
    """BlockSpec staging one (S, D) tile per (batch, head) grid point."""
    return pl.BlockSpec((None, None, s, d), lambda b, h: (b, h, 0, 0))


def _pad_spec(s):
    """BlockSpec staging the [S] key-validity row per batch grid point."""
    return pl.BlockSpec((None, s), lambda b, h: (b, 0))


def _lse_spec(s):
    return pl.BlockSpec((None, None, s), lambda b, h: (b, h, 0))


def _attention_fwd_p(q, k, v, pad_mask, causal):
    b, h, s, d = q.shape
    has_pad = pad_mask is not None
    kernel = functools.partial(_fwd_kernel, causal, has_pad)
    in_specs = [_bh_spec(s, d)] * 3 + ([_pad_spec(s)] if has_pad else [])
    out_shape = [
        jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        jax.ShapeDtypeStruct((b, h, s), q.dtype),
    ]
    args = (q, k, v) + ((pad_mask,) if has_pad else ())
    o, lse = pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=in_specs,
        out_specs=[_bh_spec(s, d), _lse_spec(s)],
        out_shape=out_shape,
        interpret=True,
    )(*args)
    return o, lse


def _attention_bwd_p(q, k, v, pad_mask, o, lse, do, causal):
    b, h, s, d = q.shape
    has_pad = pad_mask is not None
    kernel = functools.partial(_bwd_kernel, causal, has_pad)
    in_specs = (
        [_bh_spec(s, d)] * 3
        + ([_pad_spec(s)] if has_pad else [])
        + [_bh_spec(s, d), _lse_spec(s), _bh_spec(s, d)]
    )
    out_shape = [jax.ShapeDtypeStruct((b, h, s, d), q.dtype)] * 3
    args = (q, k, v) + ((pad_mask,) if has_pad else ()) + (o, lse, do)
    dq, dk, dv = pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=in_specs,
        out_specs=[_bh_spec(s, d)] * 3,
        out_shape=out_shape,
        interpret=True,
    )(*args)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def attention(q, k, v, pad_mask, causal):
    """Multi-head attention via the Pallas kernels.

    q, k, v: [B, H, S, D]; pad_mask: [B, S] float or None; causal: static.
    Differentiable w.r.t. q, k, v (pad_mask gets a zero cotangent).
    """
    o, _ = _attention_fwd_p(q, k, v, pad_mask, causal)
    return o


def _attention_vjp_fwd(q, k, v, pad_mask, causal):
    o, lse = _attention_fwd_p(q, k, v, pad_mask, causal)
    return o, (q, k, v, pad_mask, o, lse)


def _attention_vjp_bwd(causal, res, do):
    q, k, v, pad_mask, o, lse = res
    dq, dk, dv = _attention_bwd_p(q, k, v, pad_mask, o, lse, do, causal)
    dpad = None if pad_mask is None else jnp.zeros_like(pad_mask)
    return dq, dk, dv, dpad


attention.defvjp(_attention_vjp_fwd, _attention_vjp_bwd)
