"""L2 — the JAX compute graphs for every model family, with random-LTD and
TokenBypass routing wired through the middle layers.

All step functions are *flat*: they take/return plain arrays in the order
recorded in artifacts/manifest.json, because the Rust coordinator threads
the state tuple positionally. Three step kinds per family:

* ``init(seed)``              -> state  (= params ++ adam_m ++ adam_v)
* ``train(state, t, lr, batch..., [keep_idx])`` -> state', loss, loss_sum, tok
* ``eval(params, batch...)``  -> loss_sum, tok

Routing modes (DESIGN.md §random-LTD):

* ``plain``  — every layer sees the full sequence.
* ``ltd``    — random layerwise token dropping: every *middle* layer
  independently gathers its own kept subset (indices supplied by the Rust
  dropper, sorted ascending so causal order is preserved), runs the layer on
  the short sequence, and scatters the result back order-preservingly. The
  first and last layers always see the full sequence (§3.2 "Layers without
  Token Dropping").
* ``bypass`` — the TokenBypass baseline: one kept subset is gathered before
  the middle block, all middle layers run on it, and the block output is
  combined at the end; dropped tokens skip the entire block (sandwich rule).

The attention and loss hot spots call the L1 Pallas kernels.
"""

import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from .configs import FamilyConfig, Variant, batch_input_specs, param_specs
from .kernels.attention import attention
from .kernels.softmax_xent import softmax_xent

Params = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Parameter plumbing


def unflatten(cfg: FamilyConfig, flat: List[jax.Array]) -> Params:
    specs = param_specs(cfg)
    assert len(flat) == len(specs), (len(flat), len(specs))
    return {name: arr for (name, _), arr in zip(specs, flat)}


def flatten(cfg: FamilyConfig, params: Params) -> List[jax.Array]:
    return [params[name] for name, _ in param_specs(cfg)]


def init_params(cfg: FamilyConfig, seed) -> Params:
    """Initialize parameters from a u32 seed (0.02-scaled normals)."""
    key = jax.random.key(seed)
    out: Params = {}
    for name, shape in param_specs(cfg):
        base = name.split(".")[-1]
        if base.endswith("_g"):  # layernorm gains
            out[name] = jnp.ones(shape, jnp.float32)
        elif base.endswith(("_b", "_bias")) or base.startswith("b"):
            out[name] = jnp.zeros(shape, jnp.float32)
        else:
            key, sub = jax.random.split(key)
            out[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
    return out


# ---------------------------------------------------------------------------
# Transformer core


def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _split_heads(x, n_heads):
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


def _attn_sublayer(cfg: FamilyConfig, p: Params, i: int, x, pad_mask):
    h = layer_norm(x, p[f"l{i}.ln1_g"], p[f"l{i}.ln1_b"])
    q = _split_heads(h @ p[f"l{i}.wq"], cfg.n_heads)
    k = _split_heads(h @ p[f"l{i}.wk"], cfg.n_heads)
    v = _split_heads(h @ p[f"l{i}.wv"], cfg.n_heads)
    o = attention(q, k, v, pad_mask, cfg.causal)  # L1 Pallas kernel
    return x + _merge_heads(o) @ p[f"l{i}.wo"]


def _dense_ffn(p: Params, i: int, h):
    return jax.nn.gelu(h @ p[f"l{i}.w1"] + p[f"l{i}.b1"]) @ p[f"l{i}.w2"] + p[f"l{i}.b2"]


def _moe_ffn(cfg: FamilyConfig, p: Params, i: int, h):
    """Top-1 gated expert FFN (dense compute at this scale) + aux loss.

    Experts are evaluated densely and combined with a one-hot top-1 gate
    scaled by the gate probability, so expert *and* gate parameters receive
    gradients; the load-balance aux loss follows Shazeer-style
    n_e * mean(frac_e) * mean(prob_e).
    """
    e = cfg.n_experts
    gate_logits = h @ p[f"l{i}.gate_w"]          # [B, T, E]
    gate_p = jax.nn.softmax(gate_logits, axis=-1)
    top = jnp.argmax(gate_p, axis=-1)            # [B, T]
    onehot = jax.nn.one_hot(top, e, dtype=h.dtype)
    # [E, B, T, F] -> gelu -> [E, B, T, D]
    act = jax.nn.gelu(jnp.einsum("btd,edf->ebtf", h, p[f"l{i}.w1"])
                      + p[f"l{i}.b1"][:, None, None, :])
    y = jnp.einsum("ebtf,efd->ebtd", act, p[f"l{i}.w2"]) + p[f"l{i}.b2"][:, None, None, :]
    combine = onehot * gate_p                     # [B, T, E]
    out = jnp.einsum("ebtd,bte->btd", y, combine)
    frac = jnp.mean(onehot, axis=(0, 1))          # [E]
    prob = jnp.mean(gate_p, axis=(0, 1))          # [E]
    aux = e * jnp.sum(frac * prob)
    return out, aux


def _block(cfg: FamilyConfig, p: Params, i: int, x, pad_mask):
    """One transformer layer; returns (x, aux_loss)."""
    x = _attn_sublayer(cfg, p, i, x, pad_mask)
    h = layer_norm(x, p[f"l{i}.ln2_g"], p[f"l{i}.ln2_b"])
    if cfg.family == "moe" and i % 2 == 1:
        y, aux = _moe_ffn(cfg, p, i, h)
    else:
        y, aux = _dense_ffn(p, i, h), 0.0
    return x + y, aux


def _gather_tokens(x, idx):
    return x[:, idx, :]


def _combine_tokens(x_full, x_kept, idx):
    """Order-preserving combine: write processed kept tokens back in place."""
    return x_full.at[:, idx, :].set(x_kept)


def _backbone(cfg: FamilyConfig, p: Params, x, pad_mask, mode: str,
              keep_idx: Optional[jax.Array]):
    """Run all layers with the requested routing mode. Returns (x, aux)."""
    n = cfg.n_layers
    aux_total = 0.0
    if mode == "bypass" and keep_idx is not None:
        x, aux = _block(cfg, p, 0, x, pad_mask)
        aux_total += aux
        xs = _gather_tokens(x, keep_idx)
        pm = pad_mask[:, keep_idx] if pad_mask is not None else None
        for i in range(1, n - 1):
            xs, aux = _block(cfg, p, i, xs, pm)
            aux_total += aux
        x = _combine_tokens(x, xs, keep_idx)
        x, aux = _block(cfg, p, n - 1, x, pad_mask)
        return x, aux_total + aux
    for i in range(n):
        if mode == "ltd" and keep_idx is not None and 0 < i < n - 1:
            idx = keep_idx[i - 1]
            xs = _gather_tokens(x, idx)
            pm = pad_mask[:, idx] if pad_mask is not None else None
            ys, aux = _block(cfg, p, i, xs, pm)
            x = _combine_tokens(x, ys, idx)
        else:
            x, aux = _block(cfg, p, i, x, pad_mask)
        aux_total += aux
    return x, aux_total


def lm_forward(cfg: FamilyConfig, p: Params, tokens, pad_mask=None,
               mode="plain", keep_idx=None):
    """GPT/BERT/MoE forward to logits [B, S, V] (tied output head)."""
    s = tokens.shape[1]
    x = p["tok_emb"][tokens] + p["pos_emb"][:s][None]
    x, aux = _backbone(cfg, p, x, pad_mask, mode, keep_idx)
    x = layer_norm(x, p["lnf_g"], p["lnf_b"])
    return x @ p["tok_emb"].T, aux


def vit_forward(cfg: FamilyConfig, p: Params, patches, mode="plain",
                keep_idx=None):
    """ViT-style forward to class logits [B, C]."""
    b = patches.shape[0]
    x = patches @ p["patch_proj"] + p["patch_bias"]
    cls = jnp.broadcast_to(p["cls_emb"], (b, 1, cfg.d_model))
    x = jnp.concatenate([cls, x], axis=1)
    x = x + p["pos_emb"][: x.shape[1]][None]
    x, aux = _backbone(cfg, p, x, None, mode, keep_idx)
    x = layer_norm(x, p["lnf_g"], p["lnf_b"])
    return x[:, 0] @ p["head_w"] + p["head_b"], aux


# ---------------------------------------------------------------------------
# Losses


def lm_loss(cfg: FamilyConfig, p: Params, tokens, targets, loss_mask,
            pad_mask=None, mode="plain", keep_idx=None):
    logits, aux = lm_forward(cfg, p, tokens, pad_mask, mode, keep_idx)
    n = tokens.shape[0] * tokens.shape[1]
    per_tok = softmax_xent(logits.reshape(n, cfg.vocab),
                           targets.reshape(n).astype(jnp.int32))  # L1 kernel
    m = loss_mask.reshape(n)
    loss_sum = jnp.sum(per_tok * m)
    cnt = jnp.sum(m)
    mean = loss_sum / jnp.maximum(cnt, 1.0)
    if cfg.family == "moe":
        mean = mean + cfg.moe_aux_coef * aux
    return mean, (loss_sum, cnt)


def vit_loss(cfg: FamilyConfig, p: Params, patches, labels, mode="plain",
             keep_idx=None):
    logits, _ = vit_forward(cfg, p, patches, mode, keep_idx)
    per_row = softmax_xent(logits, labels.astype(jnp.int32))
    loss_sum = jnp.sum(per_row)
    cnt = jnp.float32(labels.shape[0])
    return loss_sum / cnt, (loss_sum, cnt)


# ---------------------------------------------------------------------------
# Adam + step builders


def adam_update(cfg: FamilyConfig, p, g, m, v, t, lr):
    b1, b2, eps = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps
    m2 = jax.tree.map(lambda m_, g_: b1 * m_ + (1 - b1) * g_, m, g)
    v2 = jax.tree.map(lambda v_, g_: b2 * v_ + (1 - b2) * jnp.square(g_), v, g)
    bc1 = 1.0 - jnp.power(b1, t)
    bc2 = 1.0 - jnp.power(b2, t)
    def upd(p_, m_, v_):
        mhat = m_ / bc1
        vhat = v_ / bc2
        return p_ - lr * mhat / (jnp.sqrt(vhat) + eps)
    return jax.tree.map(upd, p, m2, v2), m2, v2


def _state_len(cfg: FamilyConfig) -> int:
    return len(param_specs(cfg))


def make_init(cfg: FamilyConfig):
    def init(seed):
        p = init_params(cfg, seed)
        flat = flatten(cfg, p)
        zeros = [jnp.zeros_like(a) for a in flat]
        return tuple(flat + zeros + [jnp.zeros_like(a) for a in flat])
    return init


def _parse_batch(cfg: FamilyConfig, variant: Variant, args):
    """Split flat per-step args according to batch_input_specs order."""
    names = [n for n, _, _ in batch_input_specs(cfg, variant)]
    return dict(zip(names, args))


def make_train_step(cfg: FamilyConfig, variant: Variant):
    """Flat train step: (state..., t, lr, batch...) -> (state'..., loss, loss_sum, tok)."""
    np_ = _state_len(cfg)

    def step(*args):
        flat_p = list(args[:np_])
        flat_m = list(args[np_: 2 * np_])
        flat_v = list(args[2 * np_: 3 * np_])
        t, lr = args[3 * np_], args[3 * np_ + 1]
        batch = _parse_batch(cfg, variant, args[3 * np_ + 2:])
        params = unflatten(cfg, flat_p)
        m = unflatten(cfg, flat_m)
        v = unflatten(cfg, flat_v)
        keep_idx = batch.get("keep_idx")

        if cfg.family == "vit":
            def loss_fn(pp):
                return vit_loss(cfg, pp, batch["patches"], batch["labels"],
                                variant.mode, keep_idx)
        else:
            def loss_fn(pp):
                return lm_loss(cfg, pp, batch["tokens"], batch["targets"],
                               batch["loss_mask"], batch.get("pad_mask"),
                               variant.mode, keep_idx)

        (mean, (loss_sum, cnt)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params2, m2, v2 = adam_update(cfg, params, grads, m, v, t, lr)
        out = (flatten(cfg, params2) + flatten(cfg, m2) + flatten(cfg, v2)
               + [mean, loss_sum, cnt])
        return tuple(out)

    return step


def make_eval_step(cfg: FamilyConfig, variant: Variant):
    """Flat eval step: (params..., batch...) -> (loss_sum, tok[, n_correct])."""
    np_ = _state_len(cfg)

    def step(*args):
        params = unflatten(cfg, list(args[:np_]))
        batch = _parse_batch(cfg, variant, args[np_:])
        if cfg.family == "vit":
            logits, _ = vit_forward(cfg, params, batch["patches"])
            per_row = softmax_xent(logits, batch["labels"].astype(jnp.int32))
            correct = jnp.sum((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
            return (jnp.sum(per_row), jnp.float32(batch["labels"].shape[0]), correct)
        _, (loss_sum, cnt) = lm_loss(cfg, params, batch["tokens"],
                                     batch["targets"], batch["loss_mask"],
                                     batch.get("pad_mask"))
        return (loss_sum, cnt)

    return step
