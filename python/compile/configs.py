"""Model-family configurations and the AOT variant grid.

Shared between aot.py (lowering), model.py (step builders) and the pytest
suite, and mirrored in artifacts/manifest.json for the Rust coordinator.

Families (DESIGN.md §Substitutions — tiny stand-ins with the paper's family
*shape*):

* ``gpt``  — decoder LM              (paper: GPT-3 1.3B pretraining, Tab. 3)
* ``bert`` — encoder MLM w/ padding  (paper: BERT-large pretraining, Tab. 4)
* ``vit``  — encoder classifier      (paper: ViT finetuning, Tab. 13)
* ``moe``  — decoder LM w/ expert FFN on every other layer
                                      (paper: GPT-3 MoE 6.7B, Tab. 3 c16-17)

Variant grid: XLA needs static shapes, but curriculum learning shrinks the
sequence (seqtru/seqres) and random-LTD shrinks the *kept* length in middle
layers. We compile one executable per (family, kind, seq-bucket, routing
mode, keep-bucket); the Rust coordinator routes each step to the right one.
"""

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class FamilyConfig:
    family: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int
    batch: int
    # MoE
    n_experts: int = 0
    moe_aux_coef: float = 0.01
    # ViT
    n_classes: int = 0
    patch_dim: int = 0
    # Adam
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-8

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def causal(self) -> bool:
        return self.family in ("gpt", "moe")

    @property
    def has_pad_mask(self) -> bool:
        return self.family == "bert"


GPT = FamilyConfig("gpt", vocab=512, d_model=64, n_layers=4, n_heads=4,
                   d_ff=256, max_seq=64, batch=8)
BERT = FamilyConfig("bert", vocab=512, d_model=64, n_layers=4, n_heads=4,
                    d_ff=256, max_seq=64, batch=8)
# 16 patches of 4x4x3 synthetic "images" + 1 CLS token -> seq 17.
VIT = FamilyConfig("vit", vocab=0, d_model=64, n_layers=4, n_heads=4,
                   d_ff=256, max_seq=17, batch=8, n_classes=10, patch_dim=48)
MOE = FamilyConfig("moe", vocab=512, d_model=64, n_layers=4, n_heads=4,
                   d_ff=256, max_seq=64, batch=8, n_experts=4)

FAMILIES = {"gpt": GPT, "bert": BERT, "vit": VIT, "moe": MOE}


@dataclasses.dataclass(frozen=True)
class Variant:
    """One AOT-compiled executable."""
    family: str
    kind: str            # train | eval | init
    seq: int = 0         # sequence bucket (0 for init)
    mode: str = "plain"  # plain | ltd | bypass (train only)
    keep: int = 0        # kept middle-layer length (0 = no dropping)

    @property
    def name(self) -> str:
        if self.kind == "init":
            return f"{self.family}_init"
        if self.kind == "eval":
            return f"{self.family}_eval_s{self.seq}"
        k = "full" if self.mode == "plain" else f"{self.mode}{self.keep}"
        return f"{self.family}_train_s{self.seq}_{k}"


def keep_buckets(seq: int) -> List[int]:
    """Kept-length buckets for a sequence bucket: 1/4, 1/2, 3/4 of seq."""
    return [seq // 4, seq // 2, (3 * seq) // 4]


def vit_keep_buckets(seq: int) -> List[int]:
    # 17 tokens: keep ~1/3, ~1/2, ~3/4 (CLS always kept by the coordinator).
    return [5, 9, 13]


# Sequence buckets per family. GPT's curriculum can start as low as S/8
# (paper: d_s=80 of 2048); BERT's starts at S/4 (paper: d_s=128 of 512).
SEQ_BUCKETS = {
    "gpt": [8, 16, 32, 64],
    "bert": [16, 32, 64],
    "vit": [17],
    "moe": [16, 32, 64],
}

# (family, seq) pairs that get LTD variants. Sequences of 8 are too short
# to drop from; TokenBypass (the SOTA baseline, Tab. 11/14/15) is only
# evaluated on GPT at full sequence, matching the paper's study setup.
LTD_SEQS = {
    "gpt": [16, 32, 64],
    "bert": [32, 64],
    "vit": [17],
    "moe": [64],
}
BYPASS_SEQS = {"gpt": [64], "bert": [], "vit": [], "moe": []}


def variant_grid() -> List[Variant]:
    out: List[Variant] = []
    for fam, cfg in FAMILIES.items():
        out.append(Variant(fam, "init"))
        out.append(Variant(fam, "eval", cfg.max_seq))
        kb = vit_keep_buckets if fam == "vit" else keep_buckets
        for s in SEQ_BUCKETS[fam]:
            out.append(Variant(fam, "train", s, "plain"))
        for s in LTD_SEQS[fam]:
            for k in kb(s):
                out.append(Variant(fam, "train", s, "ltd", k))
        for s in BYPASS_SEQS[fam]:
            for k in kb(s):
                out.append(Variant(fam, "train", s, "bypass", k))
    return out


def param_specs(cfg: FamilyConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the canonical state flattening order.

    The Rust coordinator relies on this exact order (via manifest.json) to
    thread the [params..., m..., v...] state tuple through train steps.
    """
    d, f, s = cfg.d_model, cfg.d_ff, cfg.max_seq
    specs: List[Tuple[str, Tuple[int, ...]]] = []
    if cfg.family == "vit":
        specs.append(("patch_proj", (cfg.patch_dim, d)))
        specs.append(("patch_bias", (d,)))
        specs.append(("cls_emb", (d,)))
        specs.append(("pos_emb", (s, d)))
    else:
        specs.append(("tok_emb", (cfg.vocab, d)))
        specs.append(("pos_emb", (s, d)))
    for i in range(cfg.n_layers):
        moe_layer = cfg.family == "moe" and i % 2 == 1
        specs.append((f"l{i}.ln1_g", (d,)))
        specs.append((f"l{i}.ln1_b", (d,)))
        specs.append((f"l{i}.wq", (d, d)))
        specs.append((f"l{i}.wk", (d, d)))
        specs.append((f"l{i}.wv", (d, d)))
        specs.append((f"l{i}.wo", (d, d)))
        specs.append((f"l{i}.ln2_g", (d,)))
        specs.append((f"l{i}.ln2_b", (d,)))
        if moe_layer:
            e = cfg.n_experts
            specs.append((f"l{i}.gate_w", (d, e)))
            specs.append((f"l{i}.w1", (e, d, f)))
            specs.append((f"l{i}.b1", (e, f)))
            specs.append((f"l{i}.w2", (e, f, d)))
            specs.append((f"l{i}.b2", (e, d)))
        else:
            specs.append((f"l{i}.w1", (d, f)))
            specs.append((f"l{i}.b1", (f,)))
            specs.append((f"l{i}.w2", (f, d)))
            specs.append((f"l{i}.b2", (d,)))
    specs.append(("lnf_g", (d,)))
    specs.append(("lnf_b", (d,)))
    if cfg.family == "vit":
        specs.append(("head_w", (d, cfg.n_classes)))
        specs.append(("head_b", (cfg.n_classes,)))
    # LM families tie the output head to tok_emb.
    return specs


def batch_input_specs(cfg: FamilyConfig, variant: Variant):
    """Ordered (name, dtype, shape) list of per-step data inputs."""
    b, s = cfg.batch, variant.seq
    specs = []
    if cfg.family == "vit":
        specs.append(("patches", "f32", (b, s - 1, cfg.patch_dim)))
        specs.append(("labels", "i32", (b,)))
    else:
        specs.append(("tokens", "i32", (b, s)))
        specs.append(("targets", "i32", (b, s)))
        specs.append(("loss_mask", "f32", (b, s)))
        if cfg.has_pad_mask:
            specs.append(("pad_mask", "f32", (b, s)))
    if variant.kind == "train":
        if variant.mode == "ltd":
            n_mid = cfg.n_layers - 2
            specs.append(("keep_idx", "i32", (n_mid, variant.keep)))
        elif variant.mode == "bypass":
            specs.append(("keep_idx", "i32", (variant.keep,)))
    return specs
