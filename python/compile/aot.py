"""AOT lowering: every (family, kind, seq, mode, keep) variant -> HLO text.

Python runs ONCE at build time (`make artifacts`); the Rust coordinator then
loads `artifacts/*.hlo.txt` through `HloModuleProto::from_text_file` and
never touches Python again.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--force] [--only PREFIX]
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import (FAMILIES, LTD_SEQS, SEQ_BUCKETS, Variant,
                      batch_input_specs, keep_buckets, param_specs,
                      variant_grid, vit_keep_buckets)

DTYPES = {"f32": jnp.float32, "i32": jnp.int32, "u32": jnp.uint32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _state_specs(cfg):
    """(name, dtype, shape) for the full [params, m, v] state tuple."""
    ps = param_specs(cfg)
    out = []
    for prefix in ("p", "m", "v"):
        for name, shape in ps:
            out.append((f"{prefix}.{name}", "f32", tuple(shape)))
    return out


def variant_input_specs(cfg, variant):
    """Ordered (name, dtype, shape) of every executable input."""
    if variant.kind == "init":
        return [("seed", "u32", ())]
    batch = list(batch_input_specs(cfg, variant))
    if variant.kind == "eval":
        params = [(f"p.{n}", "f32", tuple(s)) for n, s in param_specs(cfg)]
        return params + batch
    state = _state_specs(cfg)
    return state + [("t", "f32", ()), ("lr", "f32", ())] + batch


def variant_output_specs(cfg, variant):
    if variant.kind == "init":
        return _state_specs(cfg)
    if variant.kind == "eval":
        out = [("loss_sum", "f32", ()), ("tok", "f32", ())]
        if cfg.family == "vit":
            out.append(("correct", "f32", ()))
        return out
    return _state_specs(cfg) + [("loss", "f32", ()), ("loss_sum", "f32", ()),
                                ("tok", "f32", ())]


def build_fn(cfg, variant):
    if variant.kind == "init":
        return M.make_init(cfg)
    if variant.kind == "eval":
        return M.make_eval_step(cfg, variant)
    return M.make_train_step(cfg, variant)


def lower_variant(cfg, variant):
    fn = build_fn(cfg, variant)
    specs = [
        jax.ShapeDtypeStruct(shape, DTYPES[dt])
        for _, dt, shape in variant_input_specs(cfg, variant)
    ]
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def manifest_entry(cfg, variant):
    def spec_json(specs):
        return [
            {"name": n, "dtype": dt, "shape": list(shape)}
            for n, dt, shape in specs
        ]

    return {
        "name": variant.name,
        "file": variant.name + ".hlo.txt",
        "family": variant.family,
        "kind": variant.kind,
        "seq": variant.seq,
        "mode": variant.mode,
        "keep": variant.keep,
        "inputs": spec_json(variant_input_specs(cfg, variant)),
        "outputs": spec_json(variant_output_specs(cfg, variant)),
    }


def family_json(cfg):
    kb = vit_keep_buckets if cfg.family == "vit" else keep_buckets
    return {
        "family": cfg.family,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "d_ff": cfg.d_ff,
        "max_seq": cfg.max_seq,
        "batch": cfg.batch,
        "n_experts": cfg.n_experts,
        "n_classes": cfg.n_classes,
        "patch_dim": cfg.patch_dim,
        "n_middle_layers": cfg.n_layers - 2,
        "seq_buckets": SEQ_BUCKETS[cfg.family],
        "ltd_seqs": LTD_SEQS[cfg.family],
        "keep_buckets": {str(s): kb(s) for s in SEQ_BUCKETS[cfg.family]},
        "n_params": len(param_specs(cfg)),
        "param_specs": [
            {"name": n, "shape": list(s)} for n, s in param_specs(cfg)
        ],
    }


def _source_digest() -> str:
    """Hash of the compile-path sources; artifacts rebuilt when it changes."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        if "__pycache__" in root:
            continue
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "..", "artifacts"))
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the artifact file already exists")
    ap.add_argument("--only", default=None,
                    help="only lower variants whose name starts with PREFIX")
    args = ap.parse_args(argv)

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    digest = _source_digest()
    stamp_path = os.path.join(out_dir, ".source_digest")
    old_digest = None
    if os.path.exists(stamp_path):
        with open(stamp_path) as f:
            old_digest = f.read().strip()
    force = args.force or (old_digest != digest)

    grid = variant_grid()
    manifest = {
        "version": 1,
        "source_digest": digest,
        "families": {f: family_json(c) for f, c in FAMILIES.items()},
        "artifacts": [],
    }
    t_all = time.time()
    n_lowered = 0
    for variant in grid:
        cfg = FAMILIES[variant.family]
        manifest["artifacts"].append(manifest_entry(cfg, variant))
        if args.only and not variant.name.startswith(args.only):
            continue
        path = os.path.join(out_dir, variant.name + ".hlo.txt")
        if not force and os.path.exists(path):
            continue
        t0 = time.time()
        text = lower_variant(cfg, variant)
        with open(path, "w") as f:
            f.write(text)
        n_lowered += 1
        print(f"  lowered {variant.name:<32} {len(text)//1024:>6} KiB "
              f"in {time.time() - t0:5.1f}s", flush=True)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(stamp_path, "w") as f:
        f.write(digest)
    print(f"aot: {n_lowered}/{len(grid)} variants lowered "
          f"({time.time() - t_all:.1f}s total) -> {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
