"""AOT pipeline tests: variant grid coverage, manifest consistency, and a
lowering smoke check (HLO text parses and references real shapes)."""

import json
import os

import jax
import pytest

from compile import aot
from compile.configs import (FAMILIES, Variant, batch_input_specs,
                             param_specs, variant_grid)

jax.config.update("jax_platform_name", "cpu")

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestVariantGrid:
    def test_every_family_has_core_kinds(self):
        grid = variant_grid()
        for fam in FAMILIES:
            kinds = {v.kind for v in grid if v.family == fam}
            assert kinds == {"init", "eval", "train"}, fam

    def test_ltd_variants_keep_less_than_seq(self):
        for v in variant_grid():
            if v.mode in ("ltd", "bypass"):
                assert 0 < v.keep < v.seq, v.name

    def test_input_specs_order_is_stable(self):
        v = Variant("bert", "train", 64, "ltd", 32)
        specs = aot.variant_input_specs(FAMILIES["bert"], v)
        names = [n for n, _, _ in specs]
        n_p = len(param_specs(FAMILIES["bert"]))
        assert names[0] == "p.tok_emb"
        assert names[n_p].startswith("m.")
        assert names[2 * n_p].startswith("v.")
        assert names[3 * n_p :] == ["t", "lr", "tokens", "targets", "loss_mask",
                                    "pad_mask", "keep_idx"]

    def test_output_specs(self):
        gpt = FAMILIES["gpt"]
        tr = aot.variant_output_specs(gpt, Variant("gpt", "train", 64))
        assert [n for n, _, _ in tr[-3:]] == ["loss", "loss_sum", "tok"]
        ev = aot.variant_output_specs(gpt, Variant("gpt", "eval", 64))
        assert len(ev) == 2
        vit_ev = aot.variant_output_specs(FAMILIES["vit"], Variant("vit", "eval", 17))
        assert [n for n, _, _ in vit_ev] == ["loss_sum", "tok", "correct"]


class TestLowering:
    def test_lower_one_variant_produces_hlo_text(self):
        cfg = FAMILIES["gpt"]
        text = aot.lower_variant(cfg, Variant("gpt", "eval", 16))
        assert text.startswith("HloModule")
        assert "f32[" in text

    def test_eval_variant_has_batch_shape(self):
        cfg = FAMILIES["gpt"]
        text = aot.lower_variant(cfg, Variant("gpt", "eval", 16))
        assert f"s32[{cfg.batch},16]" in text.replace(" ", "")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (make artifacts)",
)
class TestManifestOnDisk:
    def manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_covers_grid(self):
        m = self.manifest()
        names = {a["name"] for a in m["artifacts"]}
        for v in variant_grid():
            assert v.name in names

    def test_artifact_files_exist_and_parse_header(self):
        m = self.manifest()
        for a in m["artifacts"]:
            path = os.path.join(ARTIFACTS, a["file"])
            assert os.path.exists(path), a["name"]
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), a["name"]

    def test_manifest_shapes_match_configs(self):
        m = self.manifest()
        by_name = {a["name"]: a for a in m["artifacts"]}
        for fam, cfg in FAMILIES.items():
            fj = m["families"][fam]
            assert fj["n_params"] == len(param_specs(cfg))
            train = by_name[f"{fam}_train_s{cfg.max_seq}_full"]
            batch = batch_input_specs(cfg, Variant(fam, "train", cfg.max_seq))
            got_tail = train["inputs"][-len(batch):]
            for spec, (n, dt, shape) in zip(got_tail, batch):
                assert spec["name"] == n
                assert spec["dtype"] == dt
                assert tuple(spec["shape"]) == tuple(shape)
