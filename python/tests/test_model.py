"""L2 model tests: shapes, routing-mode invariants, optimizer behavior.

Key invariants:
* LTD with identity keep indices == plain forward (gather/combine is lossless)
* LTD/bypass with real dropping changes only what it should
* a few Adam steps reduce the loss for every family
* train step state layout round-trips (flatten/unflatten order stable)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import (BERT, FAMILIES, GPT, MOE, VIT, Variant,
                             batch_input_specs, param_specs, variant_grid)

jax.config.update("jax_platform_name", "cpu")


def _lm_batch(cfg, seq, seed=0):
    rs = np.random.RandomState(seed)
    tokens = jnp.array(rs.randint(4, cfg.vocab, (cfg.batch, seq)), jnp.int32)
    targets = jnp.array(rs.randint(4, cfg.vocab, (cfg.batch, seq)), jnp.int32)
    mask = jnp.ones((cfg.batch, seq), jnp.float32)
    return tokens, targets, mask


def _identity_keep(cfg, seq):
    n_mid = cfg.n_layers - 2
    return jnp.tile(jnp.arange(seq, dtype=jnp.int32)[None], (n_mid, 1))


class TestForward:
    def test_gpt_logits_shape(self):
        p = M.init_params(GPT, 0)
        tokens, _, _ = _lm_batch(GPT, 32)
        logits, aux = M.lm_forward(GPT, p, tokens)
        assert logits.shape == (GPT.batch, 32, GPT.vocab)
        assert aux == 0.0

    def test_gpt_causality(self):
        """Perturbing the last input token must not change earlier logits."""
        p = M.init_params(GPT, 0)
        tokens, _, _ = _lm_batch(GPT, 16)
        l1, _ = M.lm_forward(GPT, p, tokens)
        tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % GPT.vocab)
        l2, _ = M.lm_forward(GPT, p, tokens2)
        np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], rtol=1e-5, atol=1e-5)

    def test_bert_not_causal(self):
        p = M.init_params(BERT, 0)
        tokens, _, _ = _lm_batch(BERT, 16)
        pad = jnp.ones((BERT.batch, 16), jnp.float32)
        l1, _ = M.lm_forward(BERT, p, tokens, pad)
        tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % BERT.vocab)
        l2, _ = M.lm_forward(BERT, p, tokens2, pad)
        # bidirectional: earlier positions DO change
        assert not np.allclose(l1[:, 0], l2[:, 0], atol=1e-6)

    def test_bert_padding_isolated(self):
        """Padded key positions must not influence valid positions."""
        p = M.init_params(BERT, 0)
        tokens, _, _ = _lm_batch(BERT, 16)
        pad = jnp.ones((BERT.batch, 16), jnp.float32).at[:, 12:].set(0.0)
        l1, _ = M.lm_forward(BERT, p, tokens, pad)
        tokens2 = tokens.at[:, 14].set((tokens[:, 14] + 7) % BERT.vocab)
        l2, _ = M.lm_forward(BERT, p, tokens2, pad)
        np.testing.assert_allclose(l1[:, :12], l2[:, :12], rtol=1e-5, atol=1e-5)

    def test_vit_logits_shape(self):
        p = M.init_params(VIT, 0)
        patches = jnp.array(np.random.RandomState(0).randn(
            VIT.batch, VIT.max_seq - 1, VIT.patch_dim), jnp.float32)
        logits, _ = M.vit_forward(VIT, p, patches)
        assert logits.shape == (VIT.batch, VIT.n_classes)

    def test_moe_aux_loss_positive(self):
        p = M.init_params(MOE, 0)
        tokens, _, _ = _lm_batch(MOE, 16)
        _, aux = M.lm_forward(MOE, p, tokens)
        assert float(aux) >= 1.0 - 1e-4  # n_e * sum(frac*prob) >= 1 by Cauchy-Schwarz


class TestRouting:
    def test_ltd_identity_equals_plain(self):
        p = M.init_params(GPT, 1)
        tokens, _, _ = _lm_batch(GPT, 16)
        keep = _identity_keep(GPT, 16)
        l_plain, _ = M.lm_forward(GPT, p, tokens)
        l_ltd, _ = M.lm_forward(GPT, p, tokens, mode="ltd", keep_idx=keep)
        np.testing.assert_allclose(l_plain, l_ltd, rtol=1e-5, atol=1e-5)

    def test_bypass_identity_equals_plain(self):
        p = M.init_params(GPT, 1)
        tokens, _, _ = _lm_batch(GPT, 16)
        keep = jnp.arange(16, dtype=jnp.int32)
        l_plain, _ = M.lm_forward(GPT, p, tokens)
        l_byp, _ = M.lm_forward(GPT, p, tokens, mode="bypass", keep_idx=keep)
        np.testing.assert_allclose(l_plain, l_byp, rtol=1e-5, atol=1e-5)

    def test_ltd_differs_from_plain_when_dropping(self):
        p = M.init_params(GPT, 1)
        tokens, _, _ = _lm_batch(GPT, 16)
        n_mid = GPT.n_layers - 2
        keep = jnp.tile(jnp.arange(8, dtype=jnp.int32)[None] * 2, (n_mid, 1))
        l_plain, _ = M.lm_forward(GPT, p, tokens)
        l_ltd, _ = M.lm_forward(GPT, p, tokens, mode="ltd", keep_idx=keep)
        assert not np.allclose(l_plain, l_ltd, atol=1e-6)

    def test_ltd_per_layer_independent_indices(self):
        """Different middle layers may keep different token sets."""
        p = M.init_params(GPT, 2)
        tokens, _, _ = _lm_batch(GPT, 16)
        k1 = jnp.stack([jnp.arange(8, dtype=jnp.int32),
                        jnp.arange(8, dtype=jnp.int32) + 8])
        l1, _ = M.lm_forward(GPT, p, tokens, mode="ltd", keep_idx=k1)
        assert np.all(np.isfinite(l1))

    def test_ltd_grads_flow_through_dropped_tokens(self):
        """Dropped tokens skip a layer but still get gradients (residual)."""
        p = M.init_params(GPT, 3)
        tokens, targets, mask = _lm_batch(GPT, 16)
        keep = _identity_keep(GPT, 16)[:, ::2]  # keep every other token

        def loss(pp):
            mean, _ = M.lm_loss(GPT, pp, tokens, targets, mask,
                                mode="ltd", keep_idx=keep)
            return mean

        g = jax.grad(loss)(p)
        assert float(jnp.sum(jnp.abs(g["tok_emb"]))) > 0
        for i in range(GPT.n_layers):
            assert float(jnp.sum(jnp.abs(g[f"l{i}.wq"]))) > 0, f"layer {i} dead"


class TestTrainStep:
    @pytest.mark.parametrize("fam", ["gpt", "bert", "moe"])
    def test_loss_decreases(self, fam):
        cfg = FAMILIES[fam]
        var = Variant(fam, "train", 16, "plain")
        step = jax.jit(M.make_train_step(cfg, var))
        state = M.make_init(cfg)(0)
        tokens, targets, mask = _lm_batch(cfg, 16)
        # learn a fixed batch: loss must drop substantially
        extra = (jnp.ones((cfg.batch, 16), jnp.float32),) if cfg.has_pad_mask else ()
        first = last = None
        st = list(state)
        for t in range(1, 16):
            out = step(*st, jnp.float32(t), jnp.float32(1e-2),
                       tokens, targets, mask, *extra)
            st = list(out[:-3])
            loss = float(out[-3])
            first = first if first is not None else loss
            last = loss
        assert last < first * 0.6, (first, last)

    def test_vit_loss_decreases(self):
        cfg = VIT
        var = Variant("vit", "train", cfg.max_seq, "plain")
        step = jax.jit(M.make_train_step(cfg, var))
        st = list(M.make_init(cfg)(0))
        rs = np.random.RandomState(0)
        patches = jnp.array(rs.randn(cfg.batch, cfg.max_seq - 1, cfg.patch_dim),
                            jnp.float32)
        labels = jnp.array(rs.randint(0, cfg.n_classes, (cfg.batch,)), jnp.int32)
        first = last = None
        for t in range(1, 16):
            out = step(*st, jnp.float32(t), jnp.float32(1e-2), patches, labels)
            st = list(out[:-3])
            loss = float(out[-3])
            first = first if first is not None else loss
            last = loss
        assert last < first * 0.6

    def test_train_step_ltd_runs(self):
        cfg = GPT
        var = Variant("gpt", "train", 16, "ltd", 8)
        step = jax.jit(M.make_train_step(cfg, var))
        st = list(M.make_init(cfg)(0))
        tokens, targets, mask = _lm_batch(cfg, 16)
        keep = _identity_keep(cfg, 16)[:, :8]
        out = step(*st, jnp.float32(1), jnp.float32(1e-3),
                   tokens, targets, mask, keep)
        assert np.isfinite(float(out[-3]))

    def test_eval_step_matches_loss(self):
        cfg = GPT
        ev = jax.jit(M.make_eval_step(cfg, Variant("gpt", "eval", 16)))
        p = M.init_params(cfg, 0)
        tokens, targets, mask = _lm_batch(cfg, 16)
        loss_sum, cnt = ev(*M.flatten(cfg, p), tokens, targets, mask)
        mean, (ls, c) = M.lm_loss(cfg, p, tokens, targets, mask)
        np.testing.assert_allclose(float(loss_sum), float(ls), rtol=1e-6)
        assert float(cnt) == cfg.batch * 16


class TestStateLayout:
    @pytest.mark.parametrize("fam", list(FAMILIES))
    def test_flatten_roundtrip(self, fam):
        cfg = FAMILIES[fam]
        p = M.init_params(cfg, 7)
        flat = M.flatten(cfg, p)
        p2 = M.unflatten(cfg, flat)
        assert set(p) == set(p2)
        for k in p:
            np.testing.assert_array_equal(p[k], p2[k])

    @pytest.mark.parametrize("fam", list(FAMILIES))
    def test_init_state_length(self, fam):
        cfg = FAMILIES[fam]
        state = M.make_init(cfg)(0)
        assert len(state) == 3 * len(param_specs(cfg))
        for (name, shape), arr in zip(param_specs(cfg), state):
            assert tuple(arr.shape) == tuple(shape), name

    def test_variant_grid_names_unique(self):
        names = [v.name for v in variant_grid()]
        assert len(names) == len(set(names))
        assert len(names) > 35

    def test_batch_specs_cover_modes(self):
        v = Variant("gpt", "train", 64, "ltd", 32)
        names = [n for n, _, _ in batch_input_specs(GPT, v)]
        assert names == ["tokens", "targets", "loss_mask", "keep_idx"]
        v2 = Variant("bert", "train", 64, "plain")
        names2 = [n for n, _, _ in batch_input_specs(BERT, v2)]
        assert names2 == ["tokens", "targets", "loss_mask", "pad_mask"]
