"""L1 kernel correctness: Pallas vs pure-jnp oracle, values and gradients.

hypothesis sweeps shapes (and a bf16 smoke check); assert_allclose against
ref.py is the core correctness signal for everything the Rust side runs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention
from compile.kernels.ref import (attention_ref, softmax_xent_grad_ref,
                                 softmax_xent_ref)
from compile.kernels.softmax_xent import softmax_xent

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def _qkv(seed, b, h, s, d):
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    return _rand(k1, (b, h, s, d)), _rand(k2, (b, h, s, d)), _rand(k3, (b, h, s, d))


class TestAttentionForward:
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, causal):
        q, k, v = _qkv(0, 2, 4, 16, 8)
        out = attention(q, k, v, None, causal)
        np.testing.assert_allclose(out, attention_ref(q, k, v, None, causal),
                                   rtol=1e-5, atol=1e-5)

    def test_pad_mask_matches_ref(self):
        q, k, v = _qkv(1, 3, 2, 8, 4)
        pad = jnp.array(np.random.RandomState(0).rand(3, 8) > 0.3, jnp.float32)
        pad = pad.at[:, 0].set(1.0)  # at least one valid key per row
        out = attention(q, k, v, pad, False)
        np.testing.assert_allclose(out, attention_ref(q, k, v, pad, False),
                                   rtol=1e-5, atol=1e-5)

    def test_causal_ignores_future(self):
        """Changing future tokens must not change past outputs."""
        q, k, v = _qkv(2, 1, 2, 8, 4)
        out1 = attention(q, k, v, None, True)
        k2 = k.at[:, :, -1, :].add(100.0)
        v2 = v.at[:, :, -1, :].add(100.0)
        out2 = attention(q, k2, v2, None, True)
        np.testing.assert_allclose(out1[:, :, :-1], out2[:, :, :-1],
                                   rtol=1e-5, atol=1e-5)

    @settings(max_examples=12, deadline=None)
    @given(b=st.integers(1, 3), h=st.sampled_from([1, 2, 4]),
           s=st.sampled_from([4, 8, 17, 32]), d=st.sampled_from([4, 8, 16]),
           causal=st.booleans(), seed=st.integers(0, 99))
    def test_hypothesis_shapes(self, b, h, s, d, causal, seed):
        q, k, v = _qkv(seed, b, h, s, d)
        out = attention(q, k, v, None, causal)
        np.testing.assert_allclose(out, attention_ref(q, k, v, None, causal),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16_runs_finite(self):
        q, k, v = (x.astype(jnp.bfloat16) for x in _qkv(3, 2, 2, 8, 4))
        out = attention(q, k, v, None, True)
        assert out.dtype == jnp.bfloat16
        assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))


class TestAttentionGrad:
    @pytest.mark.parametrize("causal", [True, False])
    def test_grads_match_ref(self, causal):
        q, k, v = _qkv(4, 2, 2, 12, 8)

        def f_kernel(q, k, v):
            return jnp.sum(jnp.sin(attention(q, k, v, None, causal)))

        def f_ref(q, k, v):
            return jnp.sum(jnp.sin(attention_ref(q, k, v, None, causal)))

        gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_pad_grads_match_ref(self):
        q, k, v = _qkv(5, 2, 2, 8, 4)
        pad = jnp.ones((2, 8), jnp.float32).at[:, 6:].set(0.0)

        def f_kernel(q, k, v):
            return jnp.sum(attention(q, k, v, pad, False) ** 2)

        def f_ref(q, k, v):
            return jnp.sum(attention_ref(q, k, v, pad, False) ** 2)

        gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    @settings(max_examples=8, deadline=None)
    @given(s=st.sampled_from([4, 8, 16]), d=st.sampled_from([4, 8]),
           seed=st.integers(0, 99))
    def test_hypothesis_grads(self, s, d, seed):
        q, k, v = _qkv(seed, 1, 2, s, d)
        gk = jax.grad(lambda a: jnp.sum(attention(a, k, v, None, True)))(q)
        gr = jax.grad(lambda a: jnp.sum(attention_ref(a, k, v, None, True)))(q)
        np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-4)


class TestSoftmaxXent:
    def test_matches_ref(self):
        key = jax.random.key(0)
        logits = _rand(key, (128, 512)) * 3.0
        tgt = jax.random.randint(jax.random.key(1), (128,), 0, 512)
        out = softmax_xent(logits, tgt)
        np.testing.assert_allclose(out, softmax_xent_ref(logits, tgt),
                                   rtol=1e-5, atol=1e-5)

    def test_grad_matches_ref(self):
        logits = _rand(jax.random.key(2), (64, 67)) * 2.0
        tgt = jax.random.randint(jax.random.key(3), (64,), 0, 67)
        w = _rand(jax.random.key(4), (64,))
        gk = jax.grad(lambda l: jnp.sum(softmax_xent(l, tgt) * w))(logits)
        gr = softmax_xent_grad_ref(logits, tgt, w)
        np.testing.assert_allclose(gk, gr, rtol=1e-5, atol=1e-5)

    def test_non_divisible_rows(self):
        """Row counts that don't divide ROW_BLOCK still compute correctly."""
        logits = _rand(jax.random.key(5), (136, 10))
        tgt = jax.random.randint(jax.random.key(6), (136,), 0, 10)
        out = softmax_xent(logits, tgt)
        np.testing.assert_allclose(out, softmax_xent_ref(logits, tgt),
                                   rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(n=st.sampled_from([8, 64, 100, 136]),
           v=st.sampled_from([10, 67, 512]), seed=st.integers(0, 99))
    def test_hypothesis_shapes(self, n, v, seed):
        logits = _rand(jax.random.key(seed), (n, v)) * 2.0
        tgt = jax.random.randint(jax.random.key(seed + 1), (n,), 0, v)
        np.testing.assert_allclose(softmax_xent(logits, tgt),
                                   softmax_xent_ref(logits, tgt),
                                   rtol=1e-5, atol=1e-5)

    def test_loss_is_positive_and_sane(self):
        logits = jnp.zeros((16, 32))
        tgt = jnp.arange(16, dtype=jnp.int32)
        out = softmax_xent(logits, tgt)
        np.testing.assert_allclose(out, jnp.full((16,), jnp.log(32.0)),
                                   rtol=1e-6)
