//! Quickstart: train tiny-GPT twice — plain baseline vs the paper's
//! composed data-efficiency preset (CL_seqtru_voc + random-LTD) — and
//! compare quality and consumed tokens.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use dsde::config::presets;
use dsde::config::schema::RunConfig;
use dsde::exp::relative_quality;
use dsde::train::TrainEnv;

fn main() -> dsde::Result<()> {
    let steps = 80;
    println!("building environment (synthetic corpus + difficulty indexes + PJRT)...");
    let env = TrainEnv::new(600, 42)?;

    println!("training baseline ({steps} steps)...");
    let baseline = env.run(RunConfig::baseline("gpt", steps, 3e-3))?;

    println!("training composed CL+random-LTD preset ({steps} steps)...");
    let composed = env.run(presets::gpt_pretrain(steps, 3e-3, 64))?;

    println!("\n{:<28} {:>12} {:>14} {:>10} {:>9}", "case", "data tokens", "compute tokens", "eval loss", "quality");
    for r in [&baseline, &composed] {
        println!(
            "{:<28} {:>12} {:>14.0} {:>10.4} {:>8.1}%",
            r.case,
            r.data_tokens,
            r.compute_tokens,
            r.final_eval_loss,
            relative_quality(baseline.final_eval_loss, r.final_eval_loss)
        );
    }
    println!(
        "\ncomposed run consumed {:.0}% of the baseline's compute tokens \
         (CL sequence warmup × random-LTD token dropping)",
        composed.compute_tokens / baseline.compute_tokens * 100.0
    );
    println!("executable dispatch (bucket routing): {:?}", composed.dispatch);
    Ok(())
}
