//! ViT + random-LTD example (the paper's Tab. 13 scenario): finetune-style
//! training of the encoder classifier on synthetic clustered-patch images,
//! baseline vs random-LTD with MSLG to 80% of training.
//!
//! ```bash
//! make artifacts && cargo run --release --example vit_ltd
//! ```

use dsde::config::presets;
use dsde::config::schema::RunConfig;
use dsde::train::TrainEnv;

fn main() -> dsde::Result<()> {
    let steps = 80;
    let env = TrainEnv::new(200, 5)?;
    let fam = env.rt.registry.family("vit")?.clone();
    println!(
        "ViT-style model: {} layers, {} patches + CLS, {} classes",
        fam.n_layers,
        fam.max_seq - 1,
        fam.n_classes
    );

    let base = env.run(RunConfig::baseline("vit", steps, 3e-3))?;
    let ltd = env.run(presets::vit_finetune(steps, 3e-3))?;

    println!("\n{:<12} {:>14} {:>10} {:>8}", "case", "compute tokens", "top-1 acc", "saving");
    for r in [&base, &ltd] {
        println!(
            "{:<12} {:>14.0} {:>9.1}% {:>7.1}%",
            r.case,
            r.compute_tokens,
            r.final_accuracy.unwrap_or(0.0) * 100.0,
            r.saving_ratio * 100.0
        );
    }
    println!(
        "\nCLS token is pinned (never dropped) by the coordinator's dropper, matching the\n\
         paper's position-token treatment; data saving {:.2}x with accuracy {}",
        1.0 / (1.0 - ltd.saving_ratio).max(1e-9),
        if ltd.final_accuracy.unwrap_or(0.0) >= base.final_accuracy.unwrap_or(0.0) - 0.05 {
            "maintained"
        } else {
            "degraded"
        }
    );
    Ok(())
}
