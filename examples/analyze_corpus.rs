//! Data-analyzer example: generate a corpus, run the map-reduce difficulty
//! analyzer on two metrics, persist the memory-mapped index files, and
//! inspect what the curriculum will see.
//!
//! ```bash
//! cargo run --release --example analyze_corpus
//! ```

use dsde::analysis::analyzer::AnalyzerConfig;
use dsde::analysis::metrics;
use dsde::data::corpus::{Corpus, CorpusConfig};
use dsde::data::dataset::{BertDataset, GptDataset};
use dsde::data::index::DifficultyIndex;
use dsde::data::tokenizer::Tokenizer;

fn main() -> dsde::Result<()> {
    let corpus = Corpus::generate(CorpusConfig { n_docs: 5000, ..Default::default() });
    let tok = Tokenizer::from_corpus(&corpus);
    println!(
        "corpus: {} docs, {} words, vocab {} (+{} specials)",
        corpus.docs.len(),
        corpus.total_words,
        corpus.config.vocab_words,
        6
    );

    let gpt = GptDataset::build(&corpus, &tok, 64);
    let bert = BertDataset::build(&corpus, &tok, 64);
    println!("gpt: {} packed samples; bert: {} pair samples", gpt.n_samples(), bert.n_samples());

    std::fs::create_dir_all("runs")?;
    for workers in [1, 4] {
        let cfg = AnalyzerConfig { n_workers: workers, shard_size: 2048 };
        let (idx, rep) = metrics::gpt_voc(&gpt, &tok, &cfg);
        println!(
            "voc analysis with {workers} workers: {:.0} samples/s (map {:.3}s, reduce {:.3}s)",
            rep.samples_per_sec(),
            rep.map_secs,
            rep.reduce_secs
        );
        if workers == 4 {
            idx.save(std::path::Path::new("runs/gpt_voc.idx"))?;
        }
    }
    let (seqreo, _) = metrics::bert_eff_len(&bert, &AnalyzerConfig::default());
    seqreo.save(std::path::Path::new("runs/bert_seqreo.idx"))?;

    // reopen the persisted indexes zero-copy and inspect the extremes
    let voc = DifficultyIndex::open(std::path::Path::new("runs/gpt_voc.idx"))?;
    println!("\nreopened runs/gpt_voc.idx: {} entries, metric '{}'", voc.len(), voc.metric());
    let order = voc.order();
    let easiest = order[0] as usize;
    let hardest = order[order.len() - 1] as usize;
    println!(
        "easiest sample #{easiest}: voc={:.1}; hardest #{hardest}: voc={:.1}",
        voc.values()[easiest],
        voc.values()[hardest]
    );
    println!(
        "curriculum view: 1% pool = {} samples, 50% = {}, value@p50 = {:.1}",
        voc.prefix_for_value(voc.value_at_percentile(0.01)),
        voc.prefix_for_value(voc.value_at_percentile(0.5)),
        voc.value_at_percentile(0.5)
    );
    println!(
        "\nseqreo index: shortest eff len {}, longest {}",
        voc_len(&seqreo, 0),
        voc_len(&seqreo, seqreo.len() - 1)
    );
    Ok(())
}

fn voc_len(idx: &DifficultyIndex, rank: usize) -> f32 {
    idx.values()[idx.order()[rank] as usize]
}
