//! Finetuning-scenario example (the paper's GPT-2-on-PTB use case, §4.3):
//! a small held-out corpus, the seqres (reshape) curriculum metric that
//! wins in the small-batch regime, and a short random-LTD schedule.
//!
//! Demonstrates the hyperparameter-robustness claim: every tested
//! (d_s, T_c) combination is expected to match or beat the baseline.
//!
//! ```bash
//! make artifacts && cargo run --release --example finetune_curriculum
//! ```

use dsde::config::schema::*;
use dsde::train::TrainEnv;

fn main() -> dsde::Result<()> {
    let steps = 50;
    println!("finetune scenario: small corpus, {steps} steps");
    let env = TrainEnv::new(250, 99)?;
    let max_seq = env.rt.registry.family("gpt")?.max_seq;

    let baseline = env.run(RunConfig::baseline("gpt", steps, 3e-3))?;
    println!("baseline ppl: {:.3}", baseline.perplexity());

    println!("\nCL_seqres sweep (d_s × T_c):");
    let mut beat = 0;
    let mut total = 0;
    for d_s in [max_seq / 8, max_seq / 4] {
        for t_frac in [0.3, 0.7] {
            let mut cfg = RunConfig::baseline("gpt", steps, 3e-3);
            cfg.label = format!("seqres d_s={d_s} T_c={:.0}%", t_frac * 100.0);
            cfg.curriculum.push(ClConfig::new(
                Metric::SeqRes,
                Bound::Value(d_s as f64),
                Bound::Value(max_seq as f64),
                ((steps as f64 * t_frac) as u64).max(1),
            ));
            let r = env.run(cfg)?;
            total += 1;
            let better = r.perplexity() <= baseline.perplexity();
            beat += better as usize;
            println!(
                "  {:<24} ppl {:.3} ({})",
                r.label,
                r.perplexity(),
                if better { "beats baseline" } else { "worse" }
            );
        }
    }
    println!("\n{beat}/{total} combinations beat the baseline (paper Tab. 5: 16/16 for seqres)");

    // composed: short CL + short LTD (T_c < T_r per §A.3)
    let mut comp = RunConfig::baseline("gpt", steps, 3e-3);
    comp.label = "seqres+random-LTD".into();
    comp.curriculum.push(ClConfig::new(
        Metric::SeqRes,
        Bound::Value((max_seq / 8) as f64),
        Bound::Value(max_seq as f64),
        (steps / 10).max(1),
    ));
    comp.routing = Routing::RandomLtd(LtdConfig::mslg(max_seq / 4, (steps * 3 / 10).max(1)));
    let r = env.run(comp)?;
    println!("composed ppl: {:.3} (saving {:.1}%)", r.perplexity(), r.saving_ratio * 100.0);
    Ok(())
}
