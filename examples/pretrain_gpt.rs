//! End-to-end validation driver (DESIGN.md / EXPERIMENTS.md §E2E): pretrain
//! tiny-GPT on the synthetic corpus under four configurations —
//!
//!   baseline@100%, composed@100%, baseline@50%, composed@50%
//!
//! — logging the validation-loss curve of each (Fig. 5 shape), the
//! consumed-token accounting, and the paper-anchored simulated cost
//! columns. Writes `runs/pretrain_gpt_curves.csv` + a summary table.
//!
//! ```bash
//! make artifacts && cargo run --release --example pretrain_gpt [STEPS]
//! ```

use dsde::bench::Table;
use dsde::exp::cases::table3_gpt;
use dsde::exp::{relative_quality, run_cases};
use dsde::sim::CostModel;
use dsde::train::TrainEnv;

fn main() -> dsde::Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    println!("== pretrain_gpt: end-to-end driver ({steps} full-budget steps) ==");
    let env = TrainEnv::new(1500, 7)?;
    let fam = env.rt.registry.family("gpt")?.clone();
    println!(
        "model: {} layers, d={}, heads={}, vocab={}, seq={}, batch={} ({} params tensors)",
        fam.n_layers, fam.d_model, fam.n_heads, fam.vocab, fam.max_seq, fam.batch, fam.n_params
    );
    println!(
        "data: {} train samples ({} tokens), difficulty-indexed by the map-reduce analyzer",
        env.gpt_train.n_samples(),
        env.gpt_train.stream.len()
    );

    let grid = table3_gpt(steps, fam.max_seq, 1234);
    let mut cases = vec![
        grid[0].clone(),  // baseline 100%
        grid[7].clone(),  // composed 100%
        grid[11].clone(), // baseline 50%
        grid[14].clone(), // composed 50%
    ];
    for c in cases.iter_mut() {
        c.eval_every = (steps / 12).max(1);
    }
    let results = run_cases(&env, cases)?;
    let base = &results[0];
    let cost = CostModel::new(base.compute_tokens, base.wall_secs);

    // curves CSV
    let mut curves = Table::new(&["case", "step", "compute_tokens", "eval_loss"]);
    for r in &results {
        for p in &r.curve {
            curves.row(vec![
                r.label.clone(),
                p.step.to_string(),
                format!("{:.0}", p.compute_tokens),
                format!("{:.4}", p.eval_loss),
            ]);
        }
    }
    let path = curves.save_csv("pretrain_gpt_curves")?;
    println!("\nloss curves -> {}", path.display());

    let mut summary = Table::new(&[
        "case",
        "steps",
        "compute tokens",
        "wall s",
        "step ms",
        "sim V100-h",
        "sim $",
        "final loss",
        "quality",
    ]);
    for r in &results {
        let rep = cost.report(r.compute_tokens, r.wall_secs);
        summary.row(vec![
            r.label.clone(),
            r.steps.to_string(),
            format!("{:.0} ({})", r.compute_tokens, cost.saving_label(r.compute_tokens)),
            format!("{:.1}", r.wall_secs),
            format!("{:.1}", r.step_secs * 1e3),
            format!("{:.1}", rep.sim_v100_hours),
            format!("{:.0}", rep.sim_cost_usd),
            format!("{:.4}", r.final_eval_loss),
            format!("{:.1}%", relative_quality(base.final_eval_loss, r.final_eval_loss)),
        ]);
    }
    println!();
    summary.print();
    summary.save_csv("pretrain_gpt_summary")?;

    println!("\npaper-shape verdicts:");
    let v = |ok: bool| if ok { "PASS" } else { "FAIL" };
    println!(
        "  [{}] composed@100% beats baseline@100% ({:.4} vs {:.4})",
        v(results[1].final_eval_loss < results[0].final_eval_loss),
        results[1].final_eval_loss,
        results[0].final_eval_loss
    );
    println!(
        "  [{}] baseline@50% degrades ({:.4})",
        v(results[2].final_eval_loss > results[0].final_eval_loss),
        results[2].final_eval_loss
    );
    println!(
        "  [{}] composed@50% ≈ baseline@100% ({:.4}, within 2%)",
        v(results[3].final_eval_loss < results[0].final_eval_loss * 1.02),
        results[3].final_eval_loss
    );
    Ok(())
}
